"""StreamPlan: a declarative schedule IR for SSD-offloaded execution.

The paper's pipeline (§IV-A, Fig. 5/6) is a *lifecycle* — pool-slot checkout
→ async SSD read → H2D → compute → release — that the seed code hard-coded
inside ``OffloadedTrainer.train_step``.  This module lifts that lifecycle
into data: a :class:`StreamPlan` is a linear sequence of eight op kinds

* :class:`FetchOp`    — stream one unit's compute weights SSD→pool→device,
* :class:`ComputeOp`  — run one jitted stage against the resident weights,
* :class:`GradWriteOp`— spill the stage's parameter grads into the fp32
                        host flat buffer (ZeRO-Infinity's partition buffer),
* :class:`ReleaseOp`  — drop the unit's device weights,
* :class:`KVReadOp`   — make the unit's KV cache device-resident (waiting
                        out an SSD refill if the layer had spilled),
* :class:`KVWriteOp`  — land freshly produced K/V in the unit's host slot,
                        spilling onward past the residency budget,
* :class:`OverflowCheckOp` — drain the gradient write-back queue, screen
                        the flat buffer for Inf/NaN, update the loss
                        scaler (decides whether the step applies),
* :class:`OptimStepOp`— stream one unit's (master, m, v) subgroups through
                        the host Adam.  Inside the plan — rather than after
                        it — so the full-overlap executor can run step *k*'s
                        optimizer interleaved with step *k+1*'s forward
                        prefetch window (SSDTrain-style cross-step
                        pipelining, arXiv 2408.10013),

compiled once per workload from an ``OffloadableModel``:

* :func:`compile_train`  — forward + head loss/cotangent + reverse-streamed
                           backward with offloaded gradient checkpointing,
* :func:`compile_eval`   — forward + head loss only,
* :func:`compile_decode` — forward + head logits (weight-streamed serving;
                           uncached full-prefix pass),
* :func:`compile_prefill` / :func:`compile_decode_cached`
                         — the cached-decode pair: prompt pass landing
                           every layer's K/V in the spill-able cache, then
                           O(1)-context steps (checkout → fetch → KV read →
                           attend-with-cache → KV append → release/spill),
* :func:`compile_decode_verify`
                         — the speculative-decode verify step: identical
                           stream structure to ``decode_cached`` but each
                           block runs ``block_verify`` over a (B, K) draft
                           window and appends all K tokens' K/V at once
                           (host accept/rollback happens between plans).

Because the schedule is explicit, the executor (:class:`~repro.core.session.
OffloadSession`) can *look ahead*: while block *i* computes, the SSD reads
for blocks *i+1 … i+N−1* are already in flight, with N bounded by
``policy.inflight_blocks`` — the prefetch depth that sizes the buffer pool
per §IV-B but that the seed engine never exploited.  SSDTrain
(arXiv 2408.10013) and 10Cache (arXiv 2511.14124) structure offloading the
same way: an explicit prefetch/eviction schedule rather than inline calls.
"""

from __future__ import annotations

from dataclasses import dataclass

# ComputeOp stage kinds understood by the session executor.
COMPUTE_KINDS = frozenset({
    "embed",         # h = embed_apply(params, tokens)
    "block",         # h = block_apply(params, h)   [save_input => checkpoint]
    "head_loss_grad",  # loss, head grads, dh = vjp(head_loss)
    "head_loss",     # loss = head_loss(params, h, labels)        (eval)
    "head_logits",   # logits = head_logits(params, h)            (decode)
    "head_logits_last",  # logits = head_logits(params, h[:, last])  (prefill)
    "block_bwd",     # dparams, dh = vjp(block_apply)(restored checkpoint)
    "embed_bwd",     # dembed = vjp(embed_apply)(tokens cotangent)
    "block_prefill",  # h, k, v = block_prefill(params, h)   -> kv append
    "block_step",    # h, k, v = block_step(params, h, kc, vc, len)
    "block_verify",  # h, k, v = block_verify(params, h, kc, vc, len)
                     #   (B, K) spec-decode draft window; K-token append
})

_GRAD_KINDS = frozenset({"head_loss_grad", "block_bwd", "embed_bwd"})
_KV_PRODUCING_KINDS = frozenset({"block_prefill", "block_step",
                                 "block_verify"})
# KVWriteOp.mode required for each KV-producing compute kind
_KV_WRITE_MODES = {"block_prefill": "prefill", "block_step": "step",
                   "block_verify": "verify"}


@dataclass(frozen=True)
class FetchOp:
    """Check pool slots out, read the unit's weights from SSD, put on device."""

    unit: str


@dataclass(frozen=True)
class ComputeOp:
    """Run one jitted stage; ``save_input`` checkpoints the stage's
    activation input, which the unit's ``block_bwd`` stage restores."""

    unit: str
    kind: str
    save_input: bool = False


@dataclass(frozen=True)
class GradWriteOp:
    """D2H-spill the unit's parameter grads into the fp32 flat buffer."""

    unit: str


@dataclass(frozen=True)
class ReleaseOp:
    """Drop the unit's device weights (its pool slots returned at H2D time)."""

    unit: str


@dataclass(frozen=True)
class KVReadOp:
    """Make the unit's attended KV window device-resident for its
    ``block_step``: gather the window's pages out of the paged cache
    (waiting out / issuing SSD refills for spilled pages) and H2D the
    current time-bucket extent.  Like :class:`FetchOp`, the executor
    splits this into an issue half (a gather + H2D task queued on the
    staging worker inside the lookahead window, under the previous
    block's compute) and a wait half (this op, which only blocks on the
    staged device K/V) whenever ``policy.overlap`` enables the staging
    worker."""

    unit: str


@dataclass(frozen=True)
class KVWriteOp:
    """Land the unit's freshly produced K/V in its host pages, spilling
    dirty pages onward past the residency budget.  ``mode`` is validated
    against the producing compute kind: ``"step"`` appends one token to
    the tail page (``block_step``), ``"prefill"`` scatters the whole
    padded prompt window across pages (``block_prefill``), ``"verify"``
    appends a whole K-token draft window past each slot's length without
    advancing it (``block_verify`` — the host commits or rolls the
    window back after the accept decision)."""

    unit: str
    mode: str = "step"


@dataclass(frozen=True)
class OverflowCheckOp:
    """Combine the step's Inf/NaN verdict and update the loss scaler.  The
    executor first drains the asynchronous gradient writer — this op is
    the barrier that makes every GradWriteOp's D2H visible — then decides
    whether the step's OptimStepOps apply.

    ``regions`` selects the **per-subgroup screen**: each named unit's
    flat-buffer region is screened (fused bitwise pass) as its GradWriteOp
    lands — on the writer thread under full overlap — and this op only ORs
    the per-region verdicts.  The OR over any partition of the flat buffer
    equals the whole-buffer verdict (property-tested), so the barrier no
    longer pays a whole-buffer scan.  The validator requires ``regions``
    to name every grad-written unit exactly once, in gradient write order
    (screens happen at write time, so region order IS write order).  An
    empty ``regions`` keeps the legacy whole-buffer scan at the barrier
    (the chained-baseline policy measures exactly that cost)."""

    regions: tuple[str, ...] = ()


@dataclass(frozen=True)
class OptimStepOp:
    """Stream one unit's (master, m, v) subgroups through the host Adam
    and emit fresh compute weights.  Skipped when the overflow check
    rejected the step.  The executor may run it on the optimizer worker;
    per-unit readiness then gates the *next* step's FetchOp for the same
    unit (the weights on SSD must be post-update before they are re-read)
    and the next step's GradWriteOp (the flat-buffer region must be
    consumed before it is overwritten)."""

    unit: str


Op = (FetchOp | ComputeOp | GradWriteOp | ReleaseOp | KVReadOp | KVWriteOp
      | OverflowCheckOp | OptimStepOp)


class PlanError(ValueError):
    """A StreamPlan violates the checkout→compute→release lifecycle."""


@dataclass(frozen=True)
class StreamPlan:
    """A validated linear schedule over a model's offload units."""

    name: str
    ops: tuple[Op, ...]

    def __post_init__(self):
        self.validate()

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def fetch_order(self) -> tuple[str, ...]:
        """Unit names in SSD-read order — the lookahead window walks this."""
        return tuple(op.unit for op in self.ops if isinstance(op, FetchOp))

    def validate(self) -> None:
        """Enforce the §IV-A lifecycle statically.

        * a unit's weights must be resident (fetched, not yet released)
          for every ComputeOp that names it,
        * no double fetch while resident, no release of a non-resident unit,
        * every fetch is eventually released (pool capacity is returned),
        * GradWriteOp must follow a grad-producing ComputeOp for its unit,
        * ``block_bwd`` consumes a checkpoint a prior ``save_input`` op
          saved for its unit, and every saved checkpoint is consumed
          (host checkpoint memory is returned),
        * ``block_step`` / ``block_verify`` consume a prior KVReadOp for
          their unit, every KVReadOp is consumed, and every KV-producing
          compute is landed by a KVWriteOp whose ``mode`` matches the
          producing kind (one-token append vs draft-window append vs
          whole-window prefill scatter — device K/V is never silently
          dropped, nor landed at the wrong page granularity),
        * at most one OverflowCheckOp, after every GradWriteOp (it is the
          barrier that makes the flat buffer whole); when it names
          ``regions`` they must cover every grad-written unit exactly
          once, in gradient write order (the per-region screens run at
          write time — a region out of order or missing would leave a
          gradient unscreened); and every OptimStepOp follows it, names a
          unit whose grads were written, runs at most once per unit, and
          never touches a still-resident unit (the device copy would go
          stale mid-plan).
        """
        resident: set[str] = set()
        pending_grads: set[str] = set()
        saved_inputs: set[str] = set()
        kv_loaded: set[str] = set()
        pending_kv: dict[str, str] = {}   # unit -> producing compute kind
        grads_written: set[str] = set()
        grad_write_order: list[str] = []
        optim_stepped: set[str] = set()
        overflow_seen = False
        for i, op in enumerate(self.ops):
            where = f"{self.name}[{i}]"
            if isinstance(op, FetchOp):
                if op.unit in resident:
                    raise PlanError(f"{where}: fetch of already-resident "
                                    f"unit {op.unit!r}")
                resident.add(op.unit)
            elif isinstance(op, ComputeOp):
                if op.kind not in COMPUTE_KINDS:
                    raise PlanError(f"{where}: unknown compute kind "
                                    f"{op.kind!r}")
                if op.unit not in resident:
                    raise PlanError(f"{where}: compute on non-resident unit "
                                    f"{op.unit!r}")
                if op.save_input:
                    if op.unit in saved_inputs:
                        raise PlanError(f"{where}: {op.unit!r} already has a "
                                        f"saved checkpoint")
                    saved_inputs.add(op.unit)
                if op.kind == "block_bwd":
                    if op.unit not in saved_inputs:
                        raise PlanError(f"{where}: block_bwd for {op.unit!r} "
                                        f"with no saved checkpoint")
                    saved_inputs.discard(op.unit)
                if op.kind in _GRAD_KINDS:
                    pending_grads.add(op.unit)
                if op.kind in ("block_step", "block_verify"):
                    if op.unit not in kv_loaded:
                        raise PlanError(f"{where}: {op.kind} for {op.unit!r}"
                                        f" with no KV read")
                    kv_loaded.discard(op.unit)
                if op.kind in _KV_PRODUCING_KINDS:
                    if op.unit in pending_kv:
                        raise PlanError(f"{where}: {op.unit!r} already has "
                                        f"unwritten K/V")
                    pending_kv[op.unit] = op.kind
            elif isinstance(op, KVReadOp):
                if op.unit in kv_loaded:
                    raise PlanError(f"{where}: double KV read for "
                                    f"{op.unit!r}")
                kv_loaded.add(op.unit)
            elif isinstance(op, KVWriteOp):
                kind = pending_kv.pop(op.unit, None)
                if kind is None:
                    raise PlanError(f"{where}: KV write for {op.unit!r} "
                                    f"with no K/V produced")
                if op.mode not in ("step", "prefill", "verify"):
                    raise PlanError(f"{where}: unknown KV write mode "
                                    f"{op.mode!r}")
                expected = _KV_WRITE_MODES[kind]
                if op.mode != expected:
                    raise PlanError(
                        f"{where}: KV write mode {op.mode!r} for "
                        f"{op.unit!r} does not match its producing kind "
                        f"{kind!r} (expected {expected!r}: a step appends "
                        f"one token, a verify appends the draft window, "
                        f"a prefill scatters the whole prompt window)")
            elif isinstance(op, GradWriteOp):
                if op.unit not in pending_grads:
                    raise PlanError(f"{where}: grad write for {op.unit!r} "
                                    f"with no grads produced")
                if overflow_seen:
                    raise PlanError(f"{where}: grad write for {op.unit!r} "
                                    f"after the overflow check (the check "
                                    f"must see every gradient)")
                pending_grads.discard(op.unit)
                grads_written.add(op.unit)
                grad_write_order.append(op.unit)
            elif isinstance(op, OverflowCheckOp):
                if overflow_seen:
                    raise PlanError(f"{where}: duplicate overflow check")
                if not grads_written:
                    raise PlanError(f"{where}: overflow check with no "
                                    f"grads written")
                if pending_grads:
                    raise PlanError(f"{where}: overflow check with "
                                    f"unwritten grads: "
                                    f"{sorted(pending_grads)}")
                if op.regions and list(op.regions) != grad_write_order:
                    raise PlanError(
                        f"{where}: per-region screen order "
                        f"{list(op.regions)} != gradient write order "
                        f"{grad_write_order} (every written region must "
                        f"be screened exactly once, as its write lands)")
                overflow_seen = True
            elif isinstance(op, OptimStepOp):
                if not overflow_seen:
                    raise PlanError(f"{where}: optimizer step for "
                                    f"{op.unit!r} before the overflow "
                                    f"check")
                if op.unit not in grads_written:
                    raise PlanError(f"{where}: optimizer step for "
                                    f"{op.unit!r} with no written grads")
                if op.unit in optim_stepped:
                    raise PlanError(f"{where}: duplicate optimizer step "
                                    f"for {op.unit!r}")
                if op.unit in resident:
                    raise PlanError(f"{where}: optimizer step while "
                                    f"{op.unit!r} is resident (its device "
                                    f"weights would go stale)")
                optim_stepped.add(op.unit)
            elif isinstance(op, ReleaseOp):
                if op.unit not in resident:
                    raise PlanError(f"{where}: release of non-resident unit "
                                    f"{op.unit!r}")
                resident.discard(op.unit)
            else:
                raise PlanError(f"{where}: unknown op {op!r}")
        if resident:
            raise PlanError(f"{self.name}: units never released: "
                            f"{sorted(resident)}")
        if pending_grads:
            raise PlanError(f"{self.name}: grads never written: "
                            f"{sorted(pending_grads)}")
        if saved_inputs:
            raise PlanError(f"{self.name}: checkpoints never restored: "
                            f"{sorted(saved_inputs)}")
        if kv_loaded:
            raise PlanError(f"{self.name}: KV reads never consumed: "
                            f"{sorted(kv_loaded)}")
        if pending_kv:
            raise PlanError(f"{self.name}: K/V never written: "
                            f"{sorted(pending_kv)}")


# ---------------------------------------------------------------------------
# Compilers: OffloadableModel -> StreamPlan
# ---------------------------------------------------------------------------

def _unit_names(model) -> tuple[str, list[str], str]:
    """(embed, [blocks...], head) unit names, seed layout order."""
    names = [u.name for u in model.units]
    if len(names) < 2:
        raise PlanError("model needs at least an embedding and a head unit")
    return names[0], names[1:-1], names[-1]


def _forward_ops(model, *, checkpoint: bool) -> list[Op]:
    embed, blocks, _head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        ops += [FetchOp(b),
                ComputeOp(b, "block", save_input=checkpoint),
                ReleaseOp(b)]
    return ops


def compile_train(model) -> StreamPlan:
    """Forward (checkpointing block inputs) + loss/cotangent + reverse
    backward + embedding backward + overflow screen + per-unit optimizer —
    the whole training step as data.

    The OptimStepOps come last, ordered by the *next* step's fetch order
    (embed, blocks, head): under full overlap each unit's Adam write-back
    unblocks that unit's step-*k+1* prefetch, so the earliest-needed
    weights are refreshed first and the cross-step pipeline never stalls
    longer than one subgroup.
    """
    embed, blocks, head = _unit_names(model)
    ops = _forward_ops(model, checkpoint=True)
    ops += [FetchOp(head), ComputeOp(head, "head_loss_grad"),
            ReleaseOp(head), GradWriteOp(head)]
    for b in reversed(blocks):
        ops += [FetchOp(b), ComputeOp(b, "block_bwd"),
                ReleaseOp(b), GradWriteOp(b)]
    ops += [FetchOp(embed), ComputeOp(embed, "embed_bwd"),
            ReleaseOp(embed), GradWriteOp(embed)]
    # per-subgroup screen: each unit's flat region is checked as its write
    # lands; the barrier only ORs the verdicts (regions in write order)
    ops.append(OverflowCheckOp(
        regions=(head, *reversed(blocks), embed)))
    for unit in [embed, *blocks, head]:
        ops.append(OptimStepOp(unit))
    return StreamPlan("train", tuple(ops))


def compile_eval(model) -> StreamPlan:
    """Forward + head loss; no checkpointing, no grads."""
    _embed, _blocks, head = _unit_names(model)
    ops = _forward_ops(model, checkpoint=False)
    ops += [FetchOp(head), ComputeOp(head, "head_loss"), ReleaseOp(head)]
    return StreamPlan("eval", tuple(ops))


def compile_decode(model) -> StreamPlan:
    """Forward + head logits: one weight-streamed decode step (serving)."""
    if getattr(model, "head_logits", None) is None:
        raise PlanError("model has no head_logits apply; decode plans need "
                        "one (see model_adapter.make_offloadable_lm)")
    _embed, _blocks, head = _unit_names(model)
    ops = _forward_ops(model, checkpoint=False)
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode", tuple(ops))


def _require_cached_applies(model) -> None:
    for attr in ("head_logits", "block_prefill", "block_step"):
        if getattr(model, attr, None) is None:
            raise PlanError(
                f"model has no {attr} apply; cached decode plans need one "
                f"(see model_adapter.make_offloadable_lm — attention-mixer "
                f"families only)")


def compile_prefill(model) -> StreamPlan:
    """Prompt pass of cached decode: every block streams once, computes
    full-sequence attention, and lands its K/V in the spill-able cache;
    the head emits logits at the last prompt position only."""
    _require_cached_applies(model)
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        ops += [FetchOp(b), ComputeOp(b, "block_prefill"),
                KVWriteOp(b, "prefill"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits_last"),
            ReleaseOp(head)]
    return StreamPlan("prefill", tuple(ops))


def compile_decode_cached(model) -> StreamPlan:
    """One O(1)-context decode step: per block, checkout → fetch weights →
    KV read (refill from SSD if spilled) → attend-with-cache → KV append →
    release/spill.  The (batch, 1) shapes are fixed, so every stage
    compiles once per time bucket."""
    _require_cached_applies(model)
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        ops += [FetchOp(b), KVReadOp(b), ComputeOp(b, "block_step"),
                KVWriteOp(b, "step"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode_cached", tuple(ops))


def compile_decode_verify(model) -> StreamPlan:
    """One speculative-decode verify step: same stream structure as
    :func:`compile_decode_cached`, but each block runs ``block_verify``
    over a (batch, K) window of draft tokens and its KVWriteOp appends
    all K tokens' K/V past the slot lengths *without advancing them* —
    the host inspects the verify logits afterwards, then commits the
    accepted prefix (advance + drop the rejected tail's pages) via
    ``SpillableKVCache.rollback``.  K is time-bucketed by the session, so
    the per-(K, extent) trace set stays bounded."""
    _require_cached_applies(model)
    if getattr(model, "block_verify", None) is None:
        raise PlanError(
            "model has no block_verify apply; spec-decode verify plans "
            "need one (see model_adapter.make_offloadable_lm — "
            "attention-mixer families only)")
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        ops += [FetchOp(b), KVReadOp(b), ComputeOp(b, "block_verify"),
                KVWriteOp(b, "verify"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode_verify", tuple(ops))


PLAN_COMPILERS = {
    "train": compile_train,
    "eval": compile_eval,
    "decode": compile_decode,
    "prefill": compile_prefill,
    "decode_cached": compile_decode_cached,
    "decode_verify": compile_decode_verify,
}
