"""StreamPlan: a declarative schedule IR for SSD-offloaded execution.

The paper's pipeline (§IV-A, Fig. 5/6) is a *lifecycle* — pool-slot checkout
→ async SSD read → H2D → compute → release — that the seed code hard-coded
inside ``OffloadedTrainer.train_step``.  This module lifts that lifecycle
into data: a :class:`StreamPlan` is a linear sequence of twelve op kinds

* :class:`FetchOp`    — stream one unit's compute weights SSD→pool→device,
* :class:`ComputeOp`  — run one jitted stage against the resident weights,
* :class:`GradWriteOp`— spill the stage's parameter grads into the fp32
                        host flat buffer (ZeRO-Infinity's partition buffer),
* :class:`ReleaseOp`  — drop the unit's device weights,
* :class:`KVReadOp`   — make the unit's KV cache device-resident (waiting
                        out an SSD refill if the layer had spilled),
* :class:`KVWriteOp`  — land freshly produced K/V in the unit's host slot,
                        spilling onward past the residency budget,
* :class:`ActSaveOp`  — offload one block's activation checkpoint: D2H on
                        the gradient-writer thread (hidden under the next
                        block's forward compute) and, for the ``ssd``
                        tier, an onward store write that frees the host
                        copy (SSDTrain's activation leg, arXiv 2408.10013),
* :class:`ActFetchOp` — make an offloaded checkpoint device-resident for
                        its ``block_bwd``: the SSD read + H2D are issued
                        inside the lookahead window so block *i−1*'s
                        checkpoint streams back under block *i*'s backward,
* :class:`OverflowCheckOp` — drain the gradient write-back queue, screen
                        the flat buffer for Inf/NaN, update the loss
                        scaler (decides whether the step applies),
* :class:`ExpertFetchOp` / :class:`ExpertReleaseOp`
                      — the expert-paged MoE pair: stage the unit's
                        *routed* expert weights (chosen by its
                        ``block_route`` stage, predicted one step ahead by
                        the executor) as device (E, ...) stacks out of the
                        generalized page pool, and drop them after the
                        ``block_moe`` / ``block_moe_bwd`` stage consumed
                        them,
* :class:`OptimStepOp`— stream one unit's (master, m, v) subgroups through
                        the host Adam.  Inside the plan — rather than after
                        it — so the full-overlap executor can run step *k*'s
                        optimizer interleaved with step *k+1*'s forward
                        prefetch window (SSDTrain-style cross-step
                        pipelining, arXiv 2408.10013),

compiled once per workload from an ``OffloadableModel``:

* :func:`compile_train`  — forward + head loss/cotangent + reverse-streamed
                           backward with offloaded gradient checkpointing,
* :func:`compile_eval`   — forward + head loss only,
* :func:`compile_decode` — forward + head logits (weight-streamed serving;
                           uncached full-prefix pass),
* :func:`compile_prefill` / :func:`compile_decode_cached`
                         — the cached-decode pair: prompt pass landing
                           every layer's K/V in the spill-able cache, then
                           O(1)-context steps (checkout → fetch → KV read →
                           attend-with-cache → KV append → release/spill),
* :func:`compile_decode_verify`
                         — the speculative-decode verify step: identical
                           stream structure to ``decode_cached`` but each
                           block runs ``block_verify`` over a (B, K) draft
                           window and appends all K tokens' K/V at once
                           (host accept/rollback happens between plans).

Because the schedule is explicit, the executor (:class:`~repro.core.session.
OffloadSession`) can *look ahead*: while block *i* computes, the SSD reads
for blocks *i+1 … i+N−1* are already in flight, with N bounded by
``policy.inflight_blocks`` — the prefetch depth that sizes the buffer pool
per §IV-B but that the seed engine never exploited.  SSDTrain
(arXiv 2408.10013) and 10Cache (arXiv 2511.14124) structure offloading the
same way: an explicit prefetch/eviction schedule rather than inline calls.
"""

from __future__ import annotations

from dataclasses import dataclass

# ComputeOp stage kinds understood by the session executor.
COMPUTE_KINDS = frozenset({
    "embed",         # h = embed_apply(params, tokens)
    "block",         # h = block_apply(params, h)   [save_input => checkpoint]
    "head_loss_grad",  # loss, head grads, dh = vjp(head_loss)
    "head_loss",     # loss = head_loss(params, h, labels)        (eval)
    "head_logits",   # logits = head_logits(params, h)            (decode)
    "head_logits_last",  # logits = head_logits(params, h[:, last])  (prefill)
    "block_bwd",     # dparams, dh = vjp(block_apply)(restored checkpoint)
    "embed_bwd",     # dembed = vjp(embed_apply)(tokens cotangent)
    "block_prefill",  # h, k, v = block_prefill(params, h)   -> kv append
    "block_step",    # h, k, v = block_step(params, h, kc, vc, len)
    "block_verify",  # h, k, v = block_verify(params, h, kc, vc, len)
                     #   (B, K) spec-decode draft window; K-token append
    "block_recompute",  # ckpt[recompute_for] = block_apply(params, ckpt[unit])
                     #   re-derive a dropped checkpoint from the previous
                     #   block's (peeked, not consumed) checkpoint
    # --- expert-paged MoE stages (route half / expert half split) ---
    "block_route",   # hmid, idx = mixer + router top-k; idx leaves the
                     #   device so the host can fetch the routed experts
    "block_moe",     # h = block_moe(params, gate, up, down, idx, hmid):
                     #   the routed FFN against staged expert stacks
    "block_moe_bwd",  # dparams, dgate, dup, ddown, dh = vjp(full block)
                     #   with the forward's expert assignment pinned
    "block_prefill_route",  # hmid, k, v, idx  (cached-decode prompt pass)
    "block_step_route",     # hmid, k, v, idx  (one-token cached step)
    "block_verify_route",   # hmid, k, v, idx  (spec-decode draft window)
})

# Activation-checkpoint tiers a block can be assigned (`act_policy`):
#   host       D2H into pinned host memory, H2D back for block_bwd
#   ssd        D2H + SSD write on the save side; SSD read + H2D prefetched
#              under the backward pass (SSDTrain-style streamed activations)
#   recompute  no checkpoint saved: backward re-runs `block` from the
#              previous block's checkpoint (trade FLOPs for bytes)
#   device     keep the device array (offload_checkpoints=False)
ACT_TIERS = frozenset({"host", "ssd", "recompute", "device"})
# Tiers an ActSaveOp can carry (the offloaded ones).
_ACT_SAVE_TIERS = frozenset({"host", "ssd"})

_GRAD_KINDS = frozenset({"head_loss_grad", "block_bwd", "embed_bwd",
                         "block_moe_bwd"})
_KV_PRODUCING_KINDS = frozenset({"block_prefill", "block_step",
                                 "block_verify", "block_prefill_route",
                                 "block_step_route", "block_verify_route"})
# KVWriteOp.mode required for each KV-producing compute kind
_KV_WRITE_MODES = {"block_prefill": "prefill", "block_step": "step",
                   "block_verify": "verify",
                   "block_prefill_route": "prefill",
                   "block_step_route": "step",
                   "block_verify_route": "verify"}
# Compute kinds that read the paged KV cache (consume a prior KVReadOp).
_KV_CONSUMING_KINDS = frozenset({"block_step", "block_verify",
                                 "block_step_route", "block_verify_route"})
# Compute kinds that emit an expert routing decision (set the unit's
# "routed" flag an ExpertFetchOp requires).
_ROUTE_KINDS = frozenset({"block_route", "block_prefill_route",
                          "block_step_route", "block_verify_route"})
# Compute kinds that consume staged expert stacks (require ExpertFetchOp).
_EXPERT_CONSUMING_KINDS = frozenset({"block_moe", "block_moe_bwd"})


@dataclass(frozen=True)
class FetchOp:
    """Check pool slots out, read the unit's weights from SSD, put on device."""

    unit: str


@dataclass(frozen=True)
class ComputeOp:
    """Run one jitted stage; ``save_input`` checkpoints the stage's
    activation input, which the unit's ``block_bwd`` stage restores.

    ``recompute_for`` is set only on ``block_recompute`` stages: run
    ``block_apply`` with *this* unit's weights against its own (peeked,
    not consumed) checkpoint and store the output as ``recompute_for``'s
    checkpoint — the recompute leg of the per-block activation policy."""

    unit: str
    kind: str
    save_input: bool = False
    recompute_for: str | None = None


@dataclass(frozen=True)
class GradWriteOp:
    """D2H-spill the unit's parameter grads into the fp32 flat buffer."""

    unit: str


@dataclass(frozen=True)
class ReleaseOp:
    """Drop the unit's device weights (its pool slots returned at H2D time)."""

    unit: str


@dataclass(frozen=True)
class KVReadOp:
    """Make the unit's attended KV window device-resident for its
    ``block_step``: gather the window's pages out of the paged cache
    (waiting out / issuing SSD refills for spilled pages) and H2D the
    current time-bucket extent.  Like :class:`FetchOp`, the executor
    splits this into an issue half (a gather + H2D task queued on the
    staging worker inside the lookahead window, under the previous
    block's compute) and a wait half (this op, which only blocks on the
    staged device K/V) whenever ``policy.overlap`` enables the staging
    worker."""

    unit: str


@dataclass(frozen=True)
class KVWriteOp:
    """Land the unit's freshly produced K/V in its host pages, spilling
    dirty pages onward past the residency budget.  ``mode`` is validated
    against the producing compute kind: ``"step"`` appends one token to
    the tail page (``block_step``), ``"prefill"`` scatters the whole
    padded prompt window across pages (``block_prefill``), ``"verify"``
    appends a whole K-token draft window past each slot's length without
    advancing it (``block_verify`` — the host commits or rolls the
    window back after the accept decision)."""

    unit: str
    mode: str = "step"


@dataclass(frozen=True)
class OverflowCheckOp:
    """Combine the step's Inf/NaN verdict and update the loss scaler.  The
    executor first drains the asynchronous gradient writer — this op is
    the barrier that makes every GradWriteOp's D2H visible — then decides
    whether the step's OptimStepOps apply.

    ``regions`` selects the **per-subgroup screen**: each named unit's
    flat-buffer region is screened (fused bitwise pass) as its GradWriteOp
    lands — on the writer thread under full overlap — and this op only ORs
    the per-region verdicts.  The OR over any partition of the flat buffer
    equals the whole-buffer verdict (property-tested), so the barrier no
    longer pays a whole-buffer scan.  The validator requires ``regions``
    to name every grad-written unit exactly once, in gradient write order
    (screens happen at write time, so region order IS write order).  An
    empty ``regions`` keeps the legacy whole-buffer scan at the barrier
    (the chained-baseline policy measures exactly that cost)."""

    regions: tuple[str, ...] = ()


@dataclass(frozen=True)
class ActSaveOp:
    """Offload the unit's just-saved activation checkpoint: D2H into host
    memory and — for ``tier="ssd"`` — write it onward to the store, after
    which the host copy is freed.  The executor runs the body on the
    gradient-writer thread under full overlap (the forward's save D2H
    hides under the next block's compute) and inline otherwise.  A failed
    SSD write degrades gracefully: the host copy is re-marked live and
    the checkpoint serves from the host tier."""

    unit: str
    tier: str = "host"


@dataclass(frozen=True)
class ActFetchOp:
    """Make the unit's offloaded checkpoint device-resident for its
    ``block_bwd`` (or for a successor's ``block_recompute``).  Like
    FetchOp, the executor splits this: SSD reads + H2D staging for
    upcoming act fetches are issued inside the lookahead window — block
    *i−1*'s checkpoint streams back under block *i*'s ``block_bwd`` —
    and this op only waits for the staged device array."""

    unit: str


@dataclass(frozen=True)
class ExpertFetchOp:
    """Make the unit's routed expert weights device-resident as staged
    (E, ...) stacks.  The executor resolves the actual routed set from the
    unit's ``block_route`` indices (or all experts under
    ``expert_paging="all"``), ensures those pages in the expert page cache
    (SSD refills for spilled pages), memcpys them into zero-initialized
    host stacks, and H2Ds under a ``__expert__`` device slot.  Like
    FetchOp, the issue half runs inside the lookahead window against the
    *previous* step's routing (a prediction); this op verifies the staged
    set covers the actual routed set and restages on a miss."""

    unit: str


@dataclass(frozen=True)
class ExpertReleaseOp:
    """Drop the unit's staged expert stacks (the ``__expert__`` device
    slot rotates back to the staging worker).  The cached host-side pages
    stay in the expert page cache for future steps."""

    unit: str


@dataclass(frozen=True)
class OptimStepOp:
    """Stream one unit's (master, m, v) subgroups through the host Adam
    and emit fresh compute weights.  Skipped when the overflow check
    rejected the step.  The executor may run it on the optimizer worker;
    per-unit readiness then gates the *next* step's FetchOp for the same
    unit (the weights on SSD must be post-update before they are re-read)
    and the next step's GradWriteOp (the flat-buffer region must be
    consumed before it is overwritten)."""

    unit: str


Op = (FetchOp | ComputeOp | GradWriteOp | ReleaseOp | KVReadOp | KVWriteOp
      | ActSaveOp | ActFetchOp | OverflowCheckOp | OptimStepOp
      | ExpertFetchOp | ExpertReleaseOp)


class PlanError(ValueError):
    """A StreamPlan violates the checkout→compute→release lifecycle."""


@dataclass(frozen=True)
class StreamPlan:
    """A validated linear schedule over a model's offload units."""

    name: str
    ops: tuple[Op, ...]

    def __post_init__(self):
        self.validate()

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def fetch_order(self) -> tuple[str, ...]:
        """Unit names in SSD-read order — the lookahead window walks this."""
        return tuple(op.unit for op in self.ops if isinstance(op, FetchOp))

    def validate(self) -> None:
        """Enforce the §IV-A lifecycle statically.

        * a unit's weights must be resident (fetched, not yet released)
          for every ComputeOp that names it,
        * no double fetch while resident, no release of a non-resident unit,
        * every fetch is eventually released (pool capacity is returned),
        * GradWriteOp must follow a grad-producing ComputeOp for its unit,
        * checkpoints walk a per-unit lifecycle ``saved`` (a ``save_input``
          compute) → ``offloaded`` (ActSaveOp, at most once, tier host|ssd)
          → ``ready`` (ActFetchOp, at most once) → consumed (the unit's
          ``block_bwd``).  ``block_bwd`` may consume a ``saved`` checkpoint
          directly (device/host-resident modes have no Act ops) but never
          an ``offloaded`` one — the bytes are on the SSD;
          ``block_recompute`` peeks (does not consume) its own unit's
          ``saved``/``ready`` checkpoint and produces ``recompute_for``'s,
          which must not already exist.  Every checkpoint is eventually
          consumed and every ActSaveOp eventually fetched (host checkpoint
          memory and store staging are returned),
        * ``block_step`` / ``block_verify`` consume a prior KVReadOp for
          their unit, every KVReadOp is consumed, and every KV-producing
          compute is landed by a KVWriteOp whose ``mode`` matches the
          producing kind (one-token append vs draft-window append vs
          whole-window prefill scatter — device K/V is never silently
          dropped, nor landed at the wrong page granularity),
        * expert stacks walk their own lifecycle: an ExpertFetchOp needs
          its unit resident *and* routed earlier in the plan (a
          ``block_route`` / ``*_route`` compute — the flag persists, so
          the backward's re-fetch reuses the forward's routing), and may
          not double-stage; ``block_moe`` / ``block_moe_bwd`` *require*
          staged stacks; ExpertReleaseOp drops them; a ReleaseOp (and the
          plan end) with stacks still staged is an error — the
          ``__expert__`` device slot would leak,
        * at most one OverflowCheckOp, after every GradWriteOp (it is the
          barrier that makes the flat buffer whole); when it names
          ``regions`` they must cover every grad-written unit exactly
          once, in gradient write order (the per-region screens run at
          write time — a region out of order or missing would leave a
          gradient unscreened); and every OptimStepOp follows it, names a
          unit whose grads were written, runs at most once per unit, and
          never touches a still-resident unit (the device copy would go
          stale mid-plan).
        """
        resident: set[str] = set()
        pending_grads: set[str] = set()
        # unit -> checkpoint state: "saved" | "offloaded" | "ready"
        ckpt: dict[str, str] = {}
        kv_loaded: set[str] = set()
        pending_kv: dict[str, str] = {}   # unit -> producing compute kind
        routed: set[str] = set()          # units with a route decision
        expert_staged: set[str] = set()   # units with staged expert stacks
        grads_written: set[str] = set()
        grad_write_order: list[str] = []
        optim_stepped: set[str] = set()
        overflow_seen = False
        for i, op in enumerate(self.ops):
            where = f"{self.name}[{i}]"
            if isinstance(op, FetchOp):
                if op.unit in resident:
                    raise PlanError(f"{where}: fetch of already-resident "
                                    f"unit {op.unit!r}")
                resident.add(op.unit)
            elif isinstance(op, ComputeOp):
                if op.kind not in COMPUTE_KINDS:
                    raise PlanError(f"{where}: unknown compute kind "
                                    f"{op.kind!r}")
                if op.unit not in resident:
                    raise PlanError(f"{where}: compute on non-resident unit "
                                    f"{op.unit!r}")
                if op.save_input:
                    if op.kind == "block_recompute":
                        raise PlanError(f"{where}: block_recompute must not "
                                        f"save_input (it *produces* "
                                        f"{op.recompute_for!r}'s checkpoint)")
                    if op.unit in ckpt:
                        raise PlanError(f"{where}: {op.unit!r} already has a "
                                        f"saved checkpoint")
                    ckpt[op.unit] = "saved"
                if op.recompute_for is not None and \
                        op.kind != "block_recompute":
                    raise PlanError(f"{where}: recompute_for on a "
                                    f"{op.kind!r} compute (only "
                                    f"block_recompute produces a successor "
                                    f"checkpoint)")
                if op.kind == "block_recompute":
                    if op.recompute_for is None:
                        raise PlanError(f"{where}: block_recompute for "
                                        f"{op.unit!r} with no recompute_for "
                                        f"target")
                    if op.recompute_for == op.unit:
                        raise PlanError(f"{where}: block_recompute target is "
                                        f"the source unit {op.unit!r}")
                    # peeks (does not consume) its own checkpoint: the bytes
                    # must be device-reachable — saved, or fetched back
                    if ckpt.get(op.unit) not in ("saved", "ready"):
                        raise PlanError(
                            f"{where}: block_recompute for {op.unit!r} with "
                            f"no device-reachable checkpoint (state: "
                            f"{ckpt.get(op.unit)!r} — an offloaded "
                            f"checkpoint needs its ActFetchOp first)")
                    if op.recompute_for in ckpt:
                        raise PlanError(f"{where}: block_recompute target "
                                        f"{op.recompute_for!r} already has a "
                                        f"checkpoint")
                    ckpt[op.recompute_for] = "saved"
                if op.kind in ("block_bwd", "block_moe_bwd"):
                    state = ckpt.get(op.unit)
                    if state is None:
                        raise PlanError(f"{where}: {op.kind} for {op.unit!r} "
                                        f"with no saved checkpoint")
                    if state == "offloaded":
                        raise PlanError(f"{where}: {op.kind} for {op.unit!r} "
                                        f"before its ActFetchOp (the "
                                        f"checkpoint bytes are offloaded)")
                    del ckpt[op.unit]
                if op.kind in _GRAD_KINDS:
                    pending_grads.add(op.unit)
                if op.kind in _KV_CONSUMING_KINDS:
                    if op.unit not in kv_loaded:
                        raise PlanError(f"{where}: {op.kind} for {op.unit!r}"
                                        f" with no KV read")
                    kv_loaded.discard(op.unit)
                if op.kind in _ROUTE_KINDS:
                    routed.add(op.unit)
                if op.kind in _EXPERT_CONSUMING_KINDS and \
                        op.unit not in expert_staged:
                    raise PlanError(f"{where}: {op.kind} for {op.unit!r} "
                                    f"with no staged expert stacks (needs "
                                    f"an ExpertFetchOp)")
                if op.kind in _KV_PRODUCING_KINDS:
                    if op.unit in pending_kv:
                        raise PlanError(f"{where}: {op.unit!r} already has "
                                        f"unwritten K/V")
                    pending_kv[op.unit] = op.kind
            elif isinstance(op, ActSaveOp):
                if op.tier not in _ACT_SAVE_TIERS:
                    raise PlanError(f"{where}: unknown activation save tier "
                                    f"{op.tier!r} (expected one of "
                                    f"{sorted(_ACT_SAVE_TIERS)})")
                state = ckpt.get(op.unit)
                if state is None:
                    raise PlanError(f"{where}: activation save for "
                                    f"{op.unit!r} with no saved checkpoint")
                if state != "saved":
                    raise PlanError(f"{where}: duplicate activation save "
                                    f"for {op.unit!r} (state: {state!r})")
                ckpt[op.unit] = "offloaded"
            elif isinstance(op, ActFetchOp):
                state = ckpt.get(op.unit)
                if state is None:
                    raise PlanError(f"{where}: activation fetch for "
                                    f"{op.unit!r} with no checkpoint")
                if state != "offloaded":
                    raise PlanError(f"{where}: activation fetch for "
                                    f"{op.unit!r} without an ActSaveOp "
                                    f"(state: {state!r})")
                ckpt[op.unit] = "ready"
            elif isinstance(op, KVReadOp):
                if op.unit in kv_loaded:
                    raise PlanError(f"{where}: double KV read for "
                                    f"{op.unit!r}")
                kv_loaded.add(op.unit)
            elif isinstance(op, KVWriteOp):
                kind = pending_kv.pop(op.unit, None)
                if kind is None:
                    raise PlanError(f"{where}: KV write for {op.unit!r} "
                                    f"with no K/V produced")
                if op.mode not in ("step", "prefill", "verify"):
                    raise PlanError(f"{where}: unknown KV write mode "
                                    f"{op.mode!r}")
                expected = _KV_WRITE_MODES[kind]
                if op.mode != expected:
                    raise PlanError(
                        f"{where}: KV write mode {op.mode!r} for "
                        f"{op.unit!r} does not match its producing kind "
                        f"{kind!r} (expected {expected!r}: a step appends "
                        f"one token, a verify appends the draft window, "
                        f"a prefill scatters the whole prompt window)")
            elif isinstance(op, ExpertFetchOp):
                if op.unit not in resident:
                    raise PlanError(f"{where}: expert fetch for non-resident"
                                    f" unit {op.unit!r}")
                if op.unit not in routed:
                    raise PlanError(f"{where}: expert fetch for {op.unit!r} "
                                    f"with no routing decision (needs a "
                                    f"block_route/*_route compute first)")
                if op.unit in expert_staged:
                    raise PlanError(f"{where}: double expert fetch for "
                                    f"{op.unit!r}")
                expert_staged.add(op.unit)
            elif isinstance(op, ExpertReleaseOp):
                if op.unit not in expert_staged:
                    raise PlanError(f"{where}: expert release for "
                                    f"{op.unit!r} with no staged stacks")
                expert_staged.discard(op.unit)
            elif isinstance(op, GradWriteOp):
                if op.unit not in pending_grads:
                    raise PlanError(f"{where}: grad write for {op.unit!r} "
                                    f"with no grads produced")
                if overflow_seen:
                    raise PlanError(f"{where}: grad write for {op.unit!r} "
                                    f"after the overflow check (the check "
                                    f"must see every gradient)")
                pending_grads.discard(op.unit)
                grads_written.add(op.unit)
                grad_write_order.append(op.unit)
            elif isinstance(op, OverflowCheckOp):
                if overflow_seen:
                    raise PlanError(f"{where}: duplicate overflow check")
                if not grads_written:
                    raise PlanError(f"{where}: overflow check with no "
                                    f"grads written")
                if pending_grads:
                    raise PlanError(f"{where}: overflow check with "
                                    f"unwritten grads: "
                                    f"{sorted(pending_grads)}")
                if op.regions and list(op.regions) != grad_write_order:
                    raise PlanError(
                        f"{where}: per-region screen order "
                        f"{list(op.regions)} != gradient write order "
                        f"{grad_write_order} (every written region must "
                        f"be screened exactly once, as its write lands)")
                overflow_seen = True
            elif isinstance(op, OptimStepOp):
                if not overflow_seen:
                    raise PlanError(f"{where}: optimizer step for "
                                    f"{op.unit!r} before the overflow "
                                    f"check")
                if op.unit not in grads_written:
                    raise PlanError(f"{where}: optimizer step for "
                                    f"{op.unit!r} with no written grads")
                if op.unit in optim_stepped:
                    raise PlanError(f"{where}: duplicate optimizer step "
                                    f"for {op.unit!r}")
                if op.unit in resident:
                    raise PlanError(f"{where}: optimizer step while "
                                    f"{op.unit!r} is resident (its device "
                                    f"weights would go stale)")
                optim_stepped.add(op.unit)
            elif isinstance(op, ReleaseOp):
                if op.unit not in resident:
                    raise PlanError(f"{where}: release of non-resident unit "
                                    f"{op.unit!r}")
                if op.unit in expert_staged:
                    raise PlanError(f"{where}: release of {op.unit!r} with "
                                    f"expert stacks still staged (its "
                                    f"ExpertReleaseOp must come first)")
                resident.discard(op.unit)
            else:
                raise PlanError(f"{where}: unknown op {op!r}")
        if resident:
            raise PlanError(f"{self.name}: units never released: "
                            f"{sorted(resident)}")
        if pending_grads:
            raise PlanError(f"{self.name}: grads never written: "
                            f"{sorted(pending_grads)}")
        unfetched = sorted(u for u, s in ckpt.items() if s == "offloaded")
        if unfetched:
            raise PlanError(f"{self.name}: activation saves never fetched: "
                            f"{unfetched}")
        if ckpt:
            raise PlanError(f"{self.name}: checkpoints never restored: "
                            f"{sorted(ckpt)}")
        if kv_loaded:
            raise PlanError(f"{self.name}: KV reads never consumed: "
                            f"{sorted(kv_loaded)}")
        if pending_kv:
            raise PlanError(f"{self.name}: K/V never written: "
                            f"{sorted(pending_kv)}")
        if expert_staged:
            raise PlanError(f"{self.name}: expert stacks never released: "
                            f"{sorted(expert_staged)}")


# ---------------------------------------------------------------------------
# Compilers: OffloadableModel -> StreamPlan
# ---------------------------------------------------------------------------

def _unit_names(model) -> tuple[str, list[str], str]:
    """(embed, [blocks...], head) unit names, seed layout order."""
    names = [u.name for u in model.units]
    if len(names) < 2:
        raise PlanError("model needs at least an embedding and a head unit")
    return names[0], names[1:-1], names[-1]


def resolve_act_policy(blocks: list[str], spec) -> tuple[str, ...]:
    """Resolve an ``act_policy`` spec into one tier per block.

    ``spec`` may be:

    * ``None`` — every block checkpoints to pinned host memory (``host``,
      the pre-activation-streaming behaviour),
    * a single tier name — uniform, except ``"recompute"``, which becomes
      the classic checkpoint-every-other ladder (even-index blocks save to
      SSD, odd-index blocks recompute from them): a chain where *no* block
      kept a checkpoint would have nothing to recompute from,
    * a ``dict`` block-name → tier (missing blocks default to ``host``),
    * a sequence of tiers, positional, one per block.

    Chain rules (violations raise :class:`PlanError`):

    * block 0 cannot be ``recompute`` — the embedding output is not
      checkpointed, so there is no predecessor checkpoint to re-run from,
    * two consecutive ``recompute`` blocks are rejected — block *i*'s
      recompute runs from block *i−1*'s checkpoint, which must exist.
    """
    n = len(blocks)
    if spec is None:
        spec = "host"
    if isinstance(spec, str):
        if spec not in ACT_TIERS:
            raise PlanError(f"unknown act_policy tier {spec!r} (expected "
                            f"one of {sorted(ACT_TIERS)})")
        if spec == "recompute":
            tiers = tuple("ssd" if i % 2 == 0 else "recompute"
                          for i in range(n))
        else:
            tiers = (spec,) * n
    elif isinstance(spec, dict):
        unknown = sorted(set(spec) - set(blocks))
        if unknown:
            raise PlanError(f"act_policy names unknown blocks: {unknown}")
        tiers = tuple(spec.get(b, "host") for b in blocks)
    else:
        tiers = tuple(spec)
        if len(tiers) != n:
            raise PlanError(f"act_policy has {len(tiers)} entries for "
                            f"{n} blocks")
    for i, t in enumerate(tiers):
        if t not in ACT_TIERS:
            raise PlanError(f"unknown act_policy tier {t!r} for block "
                            f"{blocks[i]!r} (expected one of "
                            f"{sorted(ACT_TIERS)})")
        if t == "recompute":
            if i == 0:
                raise PlanError(f"block 0 ({blocks[0]!r}) cannot be "
                                f"'recompute': the embedding output is not "
                                f"checkpointed, so there is no predecessor "
                                f"checkpoint to re-run from")
            if tiers[i - 1] == "recompute":
                raise PlanError(
                    f"consecutive 'recompute' blocks {blocks[i - 1]!r}, "
                    f"{blocks[i]!r}: block {blocks[i]!r}'s recompute runs "
                    f"from {blocks[i - 1]!r}'s checkpoint, which "
                    f"'recompute' drops")
    return tiers


def _moe_units(model) -> frozenset:
    """Units whose expert weights live in the expert page cache (their
    ``block`` compute splits into ``block_route`` + ``block_moe``)."""
    return frozenset(getattr(model, "expert_meta", None) or ())


def _forward_ops(model, *, checkpoint: bool) -> list[Op]:
    embed, blocks, _head = _unit_names(model)
    moe = _moe_units(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        if b in moe:
            ops += [FetchOp(b),
                    ComputeOp(b, "block_route", save_input=checkpoint),
                    ExpertFetchOp(b), ComputeOp(b, "block_moe"),
                    ExpertReleaseOp(b), ReleaseOp(b)]
        else:
            ops += [FetchOp(b),
                    ComputeOp(b, "block", save_input=checkpoint),
                    ReleaseOp(b)]
    return ops


def compile_train(model, act_policy=None) -> StreamPlan:
    """Forward (checkpointing block inputs) + loss/cotangent + reverse
    backward + embedding backward + overflow screen + per-unit optimizer —
    the whole training step as data.

    ``act_policy`` (see :func:`resolve_act_policy`) picks each block's
    checkpoint tier.  ``host``/``ssd`` blocks get an ActSaveOp after their
    forward compute and an ActFetchOp before their ``block_bwd``
    (ssd-tier saves free the host copy once the store write lands — the
    forward's resident-checkpoint footprint stops growing with depth);
    ``recompute`` blocks save nothing and instead re-run the *previous*
    block forward from its (fetched-back, peeked-not-consumed) checkpoint
    just before their own ``block_bwd``; ``device`` blocks keep the
    device array (``offload_checkpoints=False``).

    The OptimStepOps come last, ordered by the *next* step's fetch order
    (embed, blocks, head): under full overlap each unit's Adam write-back
    unblocks that unit's step-*k+1* prefetch, so the earliest-needed
    weights are refreshed first and the cross-step pipeline never stalls
    longer than one subgroup.
    """
    embed, blocks, head = _unit_names(model)
    tiers = resolve_act_policy(blocks, act_policy)
    moe = _moe_units(model)
    if moe and "recompute" in tiers:
        raise PlanError(
            "act_policy 'recompute' is not supported for expert-paged MoE "
            "blocks: block_recompute re-runs block_apply, which needs the "
            "stacked expert weights the page cache replaced")
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b, tier in zip(blocks, tiers):
        if b in moe:
            ops += [FetchOp(b),
                    ComputeOp(b, "block_route", save_input=True),
                    ExpertFetchOp(b), ComputeOp(b, "block_moe")]
            if tier in _ACT_SAVE_TIERS:
                ops.append(ActSaveOp(b, tier))
            ops += [ExpertReleaseOp(b), ReleaseOp(b)]
            continue
        ops += [FetchOp(b),
                ComputeOp(b, "block", save_input=(tier != "recompute"))]
        if tier in _ACT_SAVE_TIERS:
            ops.append(ActSaveOp(b, tier))
        ops.append(ReleaseOp(b))
    ops += [FetchOp(head), ComputeOp(head, "head_loss_grad"),
            ReleaseOp(head), GradWriteOp(head)]
    # a block fetched back early to seed a successor's recompute keeps its
    # checkpoint device-resident ("ready") for its own block_bwd later —
    # no second ActFetchOp
    fetched_early: set[str] = set()
    for i in reversed(range(len(blocks))):
        b = blocks[i]
        if tiers[i] == "recompute":
            p = blocks[i - 1]
            ops.append(FetchOp(p))
            if tiers[i - 1] in _ACT_SAVE_TIERS and p not in fetched_early:
                ops.append(ActFetchOp(p))
                fetched_early.add(p)
            ops += [ComputeOp(p, "block_recompute", recompute_for=b),
                    ReleaseOp(p)]
        ops.append(FetchOp(b))
        if tiers[i] in _ACT_SAVE_TIERS and b not in fetched_early:
            ops.append(ActFetchOp(b))
        if b in moe:
            # the backward re-fetches the forward's routed experts (the
            # executor remembered the idx) and recomputes under vjp
            ops += [ExpertFetchOp(b), ComputeOp(b, "block_moe_bwd"),
                    ExpertReleaseOp(b), ReleaseOp(b), GradWriteOp(b)]
        else:
            ops += [ComputeOp(b, "block_bwd"),
                    ReleaseOp(b), GradWriteOp(b)]
    ops += [FetchOp(embed), ComputeOp(embed, "embed_bwd"),
            ReleaseOp(embed), GradWriteOp(embed)]
    # per-subgroup screen: each unit's flat region is checked as its write
    # lands; the barrier only ORs the verdicts (regions in write order)
    ops.append(OverflowCheckOp(
        regions=(head, *reversed(blocks), embed)))
    for unit in [embed, *blocks, head]:
        ops.append(OptimStepOp(unit))
    return StreamPlan("train", tuple(ops))


def compile_eval(model) -> StreamPlan:
    """Forward + head loss; no checkpointing, no grads."""
    _embed, _blocks, head = _unit_names(model)
    ops = _forward_ops(model, checkpoint=False)
    ops += [FetchOp(head), ComputeOp(head, "head_loss"), ReleaseOp(head)]
    return StreamPlan("eval", tuple(ops))


def compile_decode(model) -> StreamPlan:
    """Forward + head logits: one weight-streamed decode step (serving)."""
    if getattr(model, "head_logits", None) is None:
        raise PlanError("model has no head_logits apply; decode plans need "
                        "one (see model_adapter.make_offloadable_lm)")
    _embed, _blocks, head = _unit_names(model)
    ops = _forward_ops(model, checkpoint=False)
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode", tuple(ops))


def _require_cached_applies(model) -> None:
    attrs = ["head_logits", "block_prefill", "block_step"]
    if _moe_units(model):
        attrs += ["block_prefill_route", "block_step_route", "block_moe"]
    for attr in attrs:
        if getattr(model, attr, None) is None:
            raise PlanError(
                f"model has no {attr} apply; cached decode plans need one "
                f"(see model_adapter.make_offloadable_lm — attention-mixer "
                f"families only)")


def compile_prefill(model) -> StreamPlan:
    """Prompt pass of cached decode: every block streams once, computes
    full-sequence attention, and lands its K/V in the spill-able cache;
    the head emits logits at the last prompt position only."""
    _require_cached_applies(model)
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    moe = _moe_units(model)
    for b in blocks:
        if b in moe:
            # K/V lands right after the route half; the expert fetch's
            # SSD reads overlap the KV write
            ops += [FetchOp(b), ComputeOp(b, "block_prefill_route"),
                    KVWriteOp(b, "prefill"), ExpertFetchOp(b),
                    ComputeOp(b, "block_moe"), ExpertReleaseOp(b),
                    ReleaseOp(b)]
        else:
            ops += [FetchOp(b), ComputeOp(b, "block_prefill"),
                    KVWriteOp(b, "prefill"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits_last"),
            ReleaseOp(head)]
    return StreamPlan("prefill", tuple(ops))


def compile_decode_cached(model) -> StreamPlan:
    """One O(1)-context decode step: per block, checkout → fetch weights →
    KV read (refill from SSD if spilled) → attend-with-cache → KV append →
    release/spill.  The (batch, 1) shapes are fixed, so every stage
    compiles once per time bucket."""
    _require_cached_applies(model)
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    moe = _moe_units(model)
    for b in blocks:
        if b in moe:
            ops += [FetchOp(b), KVReadOp(b),
                    ComputeOp(b, "block_step_route"), KVWriteOp(b, "step"),
                    ExpertFetchOp(b), ComputeOp(b, "block_moe"),
                    ExpertReleaseOp(b), ReleaseOp(b)]
        else:
            ops += [FetchOp(b), KVReadOp(b), ComputeOp(b, "block_step"),
                    KVWriteOp(b, "step"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode_cached", tuple(ops))


def compile_decode_verify(model) -> StreamPlan:
    """One speculative-decode verify step: same stream structure as
    :func:`compile_decode_cached`, but each block runs ``block_verify``
    over a (batch, K) window of draft tokens and its KVWriteOp appends
    all K tokens' K/V past the slot lengths *without advancing them* —
    the host inspects the verify logits afterwards, then commits the
    accepted prefix (advance + drop the rejected tail's pages) via
    ``SpillableKVCache.rollback``.  K is time-bucketed by the session, so
    the per-(K, extent) trace set stays bounded."""
    _require_cached_applies(model)
    if getattr(model, "block_verify", None) is None:
        raise PlanError(
            "model has no block_verify apply; spec-decode verify plans "
            "need one (see model_adapter.make_offloadable_lm — "
            "attention-mixer families only)")
    moe = _moe_units(model)
    if moe and getattr(model, "block_verify_route", None) is None:
        raise PlanError(
            "model has no block_verify_route apply; expert-paged spec-"
            "decode verify plans need one "
            "(see model_adapter.make_offloadable_lm)")
    embed, blocks, head = _unit_names(model)
    ops: list[Op] = [FetchOp(embed), ComputeOp(embed, "embed"),
                     ReleaseOp(embed)]
    for b in blocks:
        if b in moe:
            ops += [FetchOp(b), KVReadOp(b),
                    ComputeOp(b, "block_verify_route"),
                    KVWriteOp(b, "verify"), ExpertFetchOp(b),
                    ComputeOp(b, "block_moe"), ExpertReleaseOp(b),
                    ReleaseOp(b)]
        else:
            ops += [FetchOp(b), KVReadOp(b), ComputeOp(b, "block_verify"),
                    KVWriteOp(b, "verify"), ReleaseOp(b)]
    ops += [FetchOp(head), ComputeOp(head, "head_logits"), ReleaseOp(head)]
    return StreamPlan("decode_verify", tuple(ops))


PLAN_COMPILERS = {
    "train": compile_train,
    "eval": compile_eval,
    "decode": compile_decode,
    "prefill": compile_prefill,
    "decode_cached": compile_decode_cached,
    "decode_verify": compile_decode_verify,
}
