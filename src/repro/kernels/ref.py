"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_overflow_check(x) -> jnp.ndarray:
    """Scalar bool: any Inf/NaN in x."""
    x32 = x.astype(jnp.float32)
    return jnp.isinf(x32).any() | jnp.isnan(x32).any()


def ref_fused_adam(p, g, m, v, step, *, lr=1e-4, beta1=0.9, beta2=0.999,
                   eps=1e-8, weight_decay=0.0, out_dtype=jnp.bfloat16):
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * jnp.square(g)
    t = jnp.asarray(step, jnp.float32)
    bias1 = 1.0 - beta1 ** t
    bias2 = 1.0 - beta2 ** t
    update = (m / bias1) / (jnp.sqrt(v / bias2) + eps)
    if weight_decay:
        update = update + weight_decay * p
    p_new = p - lr * update
    return p_new, m, v, p_new.astype(out_dtype)


def ref_swa_attention(q, k, v, *, window: int = 0, causal: bool = True):
    """Materialized-score banded attention.  Shapes as the kernel."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    n_rep = h // kh
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
