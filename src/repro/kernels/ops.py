"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only: kernels
execute their Python bodies for validation); on a real TPU backend pass
``interpret=False`` (or rely on the autodetect) to lower to Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .fused_adam import fused_adam_pallas
from .overflow_check import overflow_check_pallas
from .swa_attention import swa_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def overflow_check(x, *, block_m: int = 512, interpret: bool | None = None):
    """Fused Inf/NaN flag over any tensor (the paper's Algorithm 1 on TPU)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return overflow_check_pallas(x, block_m=block_m, interpret=interpret)


@partial(jax.jit, static_argnames=(
    "lr", "beta1", "beta2", "eps", "weight_decay", "out_dtype", "block_m",
    "interpret"))
def fused_adam(p, g, m, v, step, *, lr=1e-4, beta1=0.9, beta2=0.999,
               eps=1e-8, weight_decay=0.0, out_dtype=jnp.bfloat16,
               block_m: int = 256, interpret: bool | None = None):
    """Fused AdamW step emitting half-precision compute weights."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return fused_adam_pallas(p, g, m, v, step, lr=lr, beta1=beta1,
                             beta2=beta2, eps=eps, weight_decay=weight_decay,
                             out_dtype=out_dtype, block_m=block_m,
                             interpret=interpret)


@partial(jax.jit, static_argnames=("window", "causal", "block_q", "block_k",
                                   "interpret"))
def swa_attention(q, k, v, *, window: int = 0, causal: bool = True,
                  block_q: int = 256, block_k: int = 256,
                  interpret: bool | None = None):
    """Sliding-window flash attention (B, H, S, D) x (B, KH, S, D)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return swa_attention_pallas(q, k, v, window=window, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)
