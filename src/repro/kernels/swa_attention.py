"""Pallas TPU kernel: sliding-window flash attention (banded, online softmax).

The sub-quadratic attention variant backing ``long_500k`` on dense/MoE
architectures (DESIGN §4).  FlashAttention-style tiling adapted to the TPU
memory hierarchy: q/k/v stream HBM→VMEM in (block_q/block_k, head_dim)
tiles; softmax statistics (running max m, normalizer l) and the output
accumulator persist in VMEM scratch across the sequential k-block grid
dimension; the banded causal∧window mask is applied per tile.

GQA is handled in the index_map: query head h reads kv head h // n_rep —
no materialized head repetition (the pure-jnp path broadcasts).

Blocks entirely outside the band are skipped via ``pl.when`` predication
(a TPU grid cannot be data-dependently pruned; the HBM streaming for dead
blocks could be eliminated with a banded grid — a perf note, not a
correctness one).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                block_q, block_k, n_k, window, causal, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # band check: block indices are traced values, so the any_live
    # predication below handles causal and window limits uniformly

    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    any_live = jnp.any(mask)

    @pl.when(any_live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:, :1]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_new = alpha * l_scr[:, :1] + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def swa_attention_pallas(q, k, v, *, window: int = 0, causal: bool = True,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True):
    """Banded attention.  q: (B, H, S, D); k, v: (B, KH, S, D); KH | H.

    ``window=0`` means no band limit (plain causal flash attention).
    Returns (B, H, S, D) in q's dtype.
    """
    b, h, s, d = q.shape
    kh = k.shape[1]
    if h % kh:
        raise ValueError(f"GQA requires KH | H, got H={h}, KH={kh}")
    n_rep = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be divisible by block sizes "
                         f"({block_q}, {block_k})")
    n_q, n_k = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    out = pl.pallas_call(
        functools.partial(_swa_kernel, block_q=block_q, block_k=block_k,
                          n_k=n_k, window=window, causal=causal, scale=scale),
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, n_rep=n_rep:
                         (bi, hi // n_rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, n_rep=n_rep:
                         (bi, hi // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # normalizer
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out
