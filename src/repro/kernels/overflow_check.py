"""Pallas TPU kernel: fused gradient-overflow check (paper Algorithm 1).

TPU-native adaptation of MemAscend's fused overflow check (DESIGN §2): the
flat gradient buffer streams HBM→VMEM in (block_m, 128) tiles; each tile is
bit-cast and tested for the IEEE-754 all-ones exponent (Inf or NaN); a
single (1,1) int32 flag accumulates across the sequential TPU grid.  No
full-size temporaries are ever materialized — the kernel's extra footprint
is one VMEM tile, vs the baseline chain's 2.25× HBM spike.

The paper's early exit (Algorithm 1 line 7) maps to predicated *skipping*:
once the flag is set, later tiles still stream but skip the test work
(`pl.when`).  A TPU grid cannot abort, so bandwidth is still paid — the
compute saving mirrors the OpenMP break semantics as closely as the
hardware allows (noted in DESIGN.md).

Exponent masks: fp32 0x7F80_0000; bf16 0x7F80; fp16 0x7C00.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width
DEFAULT_BLOCK_M = 512   # (512, 128) fp32 tile = 256 KiB of VMEM

_MASKS = {
    jnp.dtype(jnp.float32): (jnp.uint32, 0x7F80_0000),
    jnp.dtype(jnp.bfloat16): (jnp.uint16, 0x7F80),
    jnp.dtype(jnp.float16): (jnp.uint16, 0x7C00),
}


def _overflow_kernel(x_ref, flag_ref, *, uint_t, mask):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        flag_ref[0, 0] = jnp.int32(0)

    @pl.when(flag_ref[0, 0] == 0)   # "early exit": skip work once flagged
    def _check():
        bits = jax.lax.bitcast_convert_type(x_ref[...], uint_t)
        hit = jnp.any((bits & uint_t(mask)) == uint_t(mask))
        flag_ref[0, 0] = hit.astype(jnp.int32)


def overflow_check_pallas(x, *, block_m: int = DEFAULT_BLOCK_M,
                          interpret: bool = True):
    """True iff any element of ``x`` is Inf or NaN.

    ``x`` may be any shape/size; it is padded (with zeros, which never
    trigger) to a (M, 128) layout.
    """
    dtype = jnp.dtype(x.dtype)
    if dtype not in _MASKS:
        raise TypeError(f"overflow check: unsupported dtype {dtype}")
    uint_t, mask = _MASKS[dtype]

    flat = x.reshape(-1)
    n = flat.size
    rows = -(-n // LANE)
    rows = -(-rows // block_m) * block_m          # multiple of block_m
    padded = jnp.zeros((rows * LANE,), dtype).at[:n].set(flat)
    tiled = padded.reshape(rows, LANE)
    grid = rows // block_m

    flag = pl.pallas_call(
        functools.partial(_overflow_kernel, uint_t=uint_t, mask=mask),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_m, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(tiled)
    return flag[0, 0] > 0
