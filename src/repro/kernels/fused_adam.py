"""Pallas TPU kernel: fused Adam step + half-precision weight emission.

The paper's host optimizer is DeepSpeedCPUAdam (fused AVX512 + OpenMP).  The
TPU-native analogue fuses, in one pass over (block_m, 128) VMEM tiles:

    m <- b1*m + (1-b1)*g        v <- b2*v + (1-b2)*g^2
    p <- p - lr*( m̂ / (sqrt(v̂)+eps) + wd*p )      (bias-corrected, AdamW)
    w16 <- cast(p)                                  (bf16 compute weights)

Five HBM streams (p, g, m, v in; p, m, v, w16 out) instead of the ~9 an
unfused chain reads/writes (separate m-update, v-update, denom, update,
cast), and zero full-size temporaries — the same "no intermediate buffers"
argument MemAscend makes for the overflow check, applied to the optimizer.

Hyperparameters are compile-time constants; the step count (for bias
correction) is a (1,1) scalar input so one compilation serves all steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_M = 256


def _adam_kernel(step_ref, p_ref, g_ref, m_ref, v_ref,
                 p_out, m_out, v_out, w16_ref, *,
                 lr, beta1, beta2, eps, weight_decay, out_dtype):
    t = step_ref[0, 0].astype(jnp.float32)
    p = p_ref[...]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    bias1 = 1.0 - jnp.exp(t * jnp.log(beta1))
    bias2 = 1.0 - jnp.exp(t * jnp.log(beta2))
    update = (m / bias1) / (jnp.sqrt(v / bias2) + eps)
    if weight_decay:
        update = update + weight_decay * p
    p = p - lr * update
    p_out[...] = p
    m_out[...] = m
    v_out[...] = v
    w16_ref[...] = p.astype(out_dtype)


def fused_adam_pallas(p, g, m, v, step, *, lr=1e-4, beta1=0.9, beta2=0.999,
                      eps=1e-8, weight_decay=0.0, out_dtype=jnp.bfloat16,
                      block_m: int = DEFAULT_BLOCK_M, interpret: bool = True):
    """One fused AdamW step.  All of p/g/m/v are fp32, any common shape.

    Returns (p_new, m_new, v_new, w16).
    """
    orig_shape = p.shape
    n = p.size
    rows = -(-n // LANE)
    rows = -(-rows // block_m) * block_m

    def tile(a):
        return jnp.zeros((rows * LANE,), jnp.float32).at[:n].set(
            a.reshape(-1).astype(jnp.float32)).reshape(rows, LANE)

    step_arr = jnp.asarray(step, jnp.int32).reshape(1, 1)
    grid = rows // block_m
    blk = pl.BlockSpec((block_m, LANE), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))

    outs = pl.pallas_call(
        functools.partial(_adam_kernel, lr=lr, beta1=beta1, beta2=beta2,
                          eps=eps, weight_decay=weight_decay,
                          out_dtype=out_dtype),
        grid=(grid,),
        in_specs=[scalar, blk, blk, blk, blk],
        out_specs=[blk, blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), out_dtype),
        ],
        interpret=interpret,
    )(step_arr, tile(p), tile(g), tile(m), tile(v))

    def untile(a, dtype):
        return a.reshape(-1)[:n].reshape(orig_shape).astype(dtype)

    p_new, m_new, v_new, w16 = outs
    return (untile(p_new, jnp.float32), untile(m_new, jnp.float32),
            untile(v_new, jnp.float32), untile(w16, out_dtype))
