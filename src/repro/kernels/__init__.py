"""Pallas TPU kernels for MemAscend's compute hot-spots.

* :mod:`overflow_check` — the paper's fused Inf/NaN scan (Algorithm 1),
* :mod:`fused_adam` — the host-optimizer analogue: fused AdamW + bf16 emit,
* :mod:`swa_attention` — banded flash attention for the long_500k shape.

``ops`` holds jitted wrappers; ``ref`` the pure-jnp oracles the tests sweep
against.  On this CPU container the kernels run in interpret mode; BlockSpec
tiling targets TPU (8,128) fp32 tiles and MXU-aligned matmul dims.
"""

from . import ops, ref
from .ops import fused_adam, overflow_check, swa_attention

__all__ = ["ops", "ref", "overflow_check", "fused_adam", "swa_attention"]
