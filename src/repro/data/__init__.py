from .pipeline import SyntheticTextDataset, DataLoader, make_batch_specs

__all__ = ["SyntheticTextDataset", "DataLoader", "make_batch_specs"]
