"""Deterministic synthetic LM data pipeline.

Fine-tuning-shaped workloads without external corpora: a seeded Markov-ish
token generator with document boundaries, packed into fixed-length training
sequences (labels shifted, cross-document positions masked with -100), with
per-process sharding for data parallelism.  Deterministic given (seed, step)
so multi-host shards never overlap and runs are reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticTextDataset:
    """Synthetic 'domain corpus' with zipfian unigrams + local structure."""

    vocab: int
    seed: int = 0
    mean_doc_len: int = 512
    bos: int = 1
    eos: int = 2

    def doc(self, index: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 20) ^ index)
        length = max(8, int(rng.exponential(self.mean_doc_len)))
        # zipf-ish unigram + a local repeat process (compressible structure)
        base = rng.zipf(1.3, size=length) % (self.vocab - 8) + 4
        out = base.copy()
        repeat = rng.random(length) < 0.3
        out[1:][repeat[1:]] = out[:-1][repeat[1:]]
        out[0] = self.bos
        out[-1] = self.eos
        return out.astype(np.int32)


class DataLoader:
    """Packs documents into (tokens, labels) batches, sharded per process."""

    def __init__(self, dataset: SyntheticTextDataset, *, batch: int,
                 seq_len: int, process_index: int = 0,
                 process_count: int = 1) -> None:
        self.ds = dataset
        self.batch = batch
        self.seq_len = seq_len
        self.process_index = process_index
        self.process_count = process_count
        self._next_doc = process_index
        self._buffer = np.empty(0, np.int32)

    def _fill(self, n_tokens: int) -> np.ndarray:
        parts = [self._buffer]
        total = self._buffer.size
        while total < n_tokens:
            doc = self.ds.doc(self._next_doc)
            self._next_doc += self.process_count   # disjoint host shards
            parts.append(doc)
            total += doc.size
        flat = np.concatenate(parts)
        self._buffer = flat[n_tokens:]
        return flat[:n_tokens]

    def next_batch(self) -> dict[str, np.ndarray]:
        n = self.batch * (self.seq_len + 1)
        flat = self._fill(n).reshape(self.batch, self.seq_len + 1)
        tokens = flat[:, :-1]
        labels = flat[:, 1:].astype(np.int32)
        # never train across a document boundary: mask positions whose
        # target is the BOS of the next document
        labels = np.where(labels == self.ds.bos, -100, labels)
        return {"tokens": np.ascontiguousarray(tokens),
                "labels": np.ascontiguousarray(labels)}


def make_batch_specs(batch: int, seq_len: int):
    import jax
    import jax.numpy as jnp
    return {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
