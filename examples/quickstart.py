"""Quickstart: MemAscend's four optimizations in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.configs import PAPER_MODELS
from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        DirectNVMeEngine, FixedBufferPool, MemoryTracker,
                        PowerOfTwoCachingAllocator, baseline_overflow_check,
                        fused_overflow_check, fmt_bytes)


def main() -> None:
    cfg = PAPER_MODELS["llama3.1-8b"]
    print(f"model: {cfg.name} ({cfg.param_count() / 1e9:.2f}B params)\n")

    # 1) Adaptive buffer pool (paper SIV-B) --------------------------------
    census = cfg.pool_census(inflight_blocks=1, shards=2)
    fixed = FixedBufferPool(census, AlignmentFreeAllocator(
        tracker=MemoryTracker(), component="p"))
    adaptive = AdaptiveBufferPool(census, AlignmentFreeAllocator(
        tracker=MemoryTracker(), component="p"))
    print(f"[1] parameter buffer pool: fixed {fmt_bytes(fixed.pool_bytes)}"
          f" -> adaptive {fmt_bytes(adaptive.pool_bytes)}"
          f"  (-{1 - adaptive.pool_bytes / fixed.pool_bytes:.1%})")

    # 2) Alignment-free pinned allocation (SIV-C) --------------------------
    req = int(2.1 * 2**30)
    t1, t2 = MemoryTracker(), MemoryTracker()
    PowerOfTwoCachingAllocator(tracker=t1, component="x").alloc(req)
    AlignmentFreeAllocator(tracker=t2, component="x").alloc(req)
    print(f"[2] pinned alloc of {fmt_bytes(req)}: pow2 reserves "
          f"{fmt_bytes(t1.live_allocated)}, alignment-free "
          f"{fmt_bytes(t2.live_allocated)}")

    # 3) Fused overflow check (SIV-D) --------------------------------------
    grads = np.random.default_rng(0).standard_normal(20_000_000).astype(
        np.float32)
    t = MemoryTracker()
    baseline_overflow_check(grads, tracker=t)
    peak_chained = t.component("overflow_tmp").peak_allocated
    t = MemoryTracker()
    fused_overflow_check(grads, tracker=t)
    peak_fused = t.component("overflow_tmp").peak_allocated
    print(f"[3] overflow check temps on a {fmt_bytes(grads.nbytes)} buffer: "
          f"chained {fmt_bytes(peak_chained)} vs fused {fmt_bytes(peak_fused)}")

    # 4) Direct NVMe engine (SIV-E) ----------------------------------------
    with tempfile.TemporaryDirectory() as root:
        eng = DirectNVMeEngine(root, n_devices=2, device_capacity=1 << 28)
        x = np.random.default_rng(1).standard_normal((1024, 1024)).astype(
            np.float32)
        eng.write("layer0/w_q", x)
        y = eng.read_new("layer0/w_q", np.float32, x.shape)
        assert np.array_equal(x, y)
        ext = eng._locations["layer0/w_q"][2]
        print(f"[4] direct NVMe engine: {fmt_bytes(x.nbytes)} striped across "
              f"{len(ext)} raw devices at LBAs "
              f"{[(e.device, e.offset) for e in ext]}")
        eng.close()

    fixed.close()
    adaptive.close()
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
