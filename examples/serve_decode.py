"""Serve a small model with batched decode requests through the registry's
serve path (KV cache / recurrent state), on any architecture family.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-4b]
      (uses the REDUCED variant of the chosen arch so it runs on CPU)
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    print(f"arch {args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}, family={cfg.family})")
    impl = build(cfg)
    params = impl.init_params(jax.random.PRNGKey(0))

    b = args.batch
    total = args.prompt_len + args.new_tokens
    cache = impl.init_cache(b, total)
    step = jax.jit(impl.decode_fn)

    rng = np.random.default_rng(0)
    prompts = rng.integers(3, cfg.vocab, size=(b, args.prompt_len),
                           dtype=np.int32)
    # feed the prompt token by token (prefill-by-decode keeps the example
    # uniform across KV-cache and recurrent-state families)
    tok = jnp.asarray(prompts[:, :1])
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, jnp.asarray(prompts[:, t:t + 1]),
                             jnp.int32(t))

    out_tokens = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(args.prompt_len, total):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * args.new_tokens / dt:.1f} tok/s)")
    for i in range(min(b, 2)):
        print(f"  request {i}: {gen[i][:16].tolist()} ...")
    print("serve OK")


if __name__ == "__main__":
    main()
