"""Weight-streamed offloaded decode: generate from a model whose weights
live on the (raw-file) NVMe store, streamed block-by-block per token through
the OffloadSession/StreamPlan machinery — serving on a host that cannot
hold the model in DRAM.

By default generation runs the cached path: a paged spill-able KV cache in
the same pinned pool arena as the weight staging slots.  K/V lives in
fixed-size time-axis pages (``--page-tokens``, default: the bucket size);
``--kv-resident`` layer-equivalents (or ``--resident-pages`` page slots)
stay host-resident and colder pages round-trip through the SSD store —
only dirty pages pay a spill write, and each block's attended window is
gathered + H2D'd on the staging worker under the previous block's compute.
``--no-cache`` falls back to the O(T²) full-prefix re-run for comparison.

With ``--requests N`` the example becomes a continuous-batching server:
N requests with ragged prompt lengths arrive as a seeded Poisson process
(``--arrival-rate`` per second) and stream through the ServingEngine —
each finishing request's slot and KV pages are reclaimed and handed to
the next queued request mid-flight, and per-request TTFT / queue-wait /
throughput metrics are printed at the end.

Run:  PYTHONPATH=src python examples/serve_offloaded_decode.py \
          [--policy memascend|zero-infinity] [--new-tokens 16] \
          [--kv-resident 2 | --resident-pages 4] [--bucket 16] \
          [--page-tokens 16] [--no-cache] [--lookahead 2] \
          [--requests 8 --arrival-rate 50]
"""

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, fmt_bytes
from repro.core.model_adapter import make_offloadable_lm
from repro.serve import (DecodeSpec, OffloadedDecoder, Request,
                         ServingEngine)

CFG = ModelConfig(name="serve-20m", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192)


def serve_requests(dec, args) -> None:
    """Continuous-batching demo: ragged Poisson arrivals through the
    per-slot request lifecycle (join / prefill-scatter / decode / retire)."""
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                         size=args.requests))
    reqs = []
    for i in range(args.requests):
        n = int(rng.integers(max(1, args.prompt_len // 2),
                             args.prompt_len + 1))
        reqs.append(Request(
            rid=f"r{i:02d}",
            prompt=rng.integers(3, CFG.vocab, size=n, dtype=np.int32),
            max_new_tokens=args.new_tokens,
            arrival=float(arrivals[i])))
    report = ServingEngine(dec).run(reqs)
    print(f"served {len(report.completed)}/{args.requests} requests "
          f"({len(report.refused)} refused) in {report.duration_s:.2f}s: "
          f"{report.tokens_per_s:.1f} tok/s aggregate, "
          f"occupancy {report.occupancy:.2f} over "
          f"{report.decode_steps} steps / {report.prefills} prefills")
    if report.completed:
        print(f"ttft p50 {report.ttft_percentile(50) * 1e3:.1f}ms  "
              f"p99 {report.ttft_percentile(99) * 1e3:.1f}ms")
    kv = dec.kv_stats
    print(f"kv: reclaims {kv['reclaims']} "
          f"({kv['reclaim_bytes'] / 1e6:.2f}MB dropped spill-free)  "
          f"dirty spills {kv['spills']}  refills {kv['refills']}")
    for r in report.requests[:3]:
        m = r.metrics
        print(f"  {r.rid} [{r.state.value}] prompt {r.prompt_len:3d}  "
              f"out {m.tokens_out:3d}  wait {1e3 * (m.queue_wait_s or 0):6.1f}ms  "
              f"ttft {1e3 * (m.ttft_s or 0):6.1f}ms  "
              f"tokens: {r.output[:8]} ...")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="memascend",
                    choices=OffloadPolicy.names())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lookahead", type=int, default=None,
                    help="prefetch window (default: policy inflight depth)")
    ap.add_argument("--no-cache", action="store_true",
                    help="O(T^2) full-prefix re-run (the PR-1 behaviour)")
    ap.add_argument("--bucket", type=int, default=16,
                    help="KV time-bucket granularity (jit once per bucket)")
    ap.add_argument("--kv-resident", type=int, default=None,
                    help="host KV budget in layer-equivalents "
                         "(default: all pages resident)")
    ap.add_argument("--page-tokens", type=int, default=None,
                    help="KV spill page size in tokens (default: bucket; "
                         "must align with it)")
    ap.add_argument("--resident-pages", type=int, default=None,
                    help="host KV budget directly in page slots "
                         "(overrides --kv-resident)")
    ap.add_argument("--requests", type=int, default=None,
                    help="serve N ragged requests through the continuous-"
                         "batching engine instead of one joint generate")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="Poisson arrival rate for --requests, per second")
    args = ap.parse_args()
    if args.requests is not None and args.no_cache:
        ap.error("--requests needs the paged KV cache (drop --no-cache)")

    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, CFG.vocab, size=(args.batch, args.prompt_len),
                           dtype=np.int32)
    decode = None
    if not args.no_cache:
        max_seq = args.prompt_len + args.new_tokens
        decode = DecodeSpec(batch=args.batch, max_seq=max_seq,
                            bucket=min(args.bucket, max_seq),
                            resident_blocks=(None if args.resident_pages
                                             else args.kv_resident),
                            page_tokens=args.page_tokens,
                            resident_pages=args.resident_pages)

    with tempfile.TemporaryDirectory(prefix="serve_offload_") as root:
        policy = (OffloadPolicy.preset(args.policy).with_store(root)
                  .with_lookahead(args.lookahead).build())
        with OffloadedDecoder(model, policy, decode=decode) as dec:
            print(f"policy {policy.name}  lookahead {dec.session.lookahead}  "
                  f"pool {fmt_bytes(dec.session.pool.pool_bytes)}  "
                  f"cache {'KV (spill-able)' if decode else 'none (O(T^2))'}")
            if args.requests is not None:
                serve_requests(dec, args)
                print("offloaded serve OK")
                return
            dec.generate(prompts, args.new_tokens)   # warmup/compile
            t0 = time.time()
            gen = dec.generate(prompts, args.new_tokens)
            dt = time.time() - t0
            stats = dec.fetch_stats
            print(f"generated {gen.shape} tokens in {dt:.2f}s "
                  f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
            print(f"fetches: {stats['n_gets']}  prefetch hits: "
                  f"{stats['prefetch_hits']}  fetch-wait: "
                  f"{stats['wait_seconds'] * 1e3:.1f}ms")
            if dec.kv_stats is not None:
                kv = dec.kv_stats
                ov = dec.kv_overlap_stats
                print(f"kv: dirty spills {kv['spills']} "
                      f"({kv['spill_bytes'] / 1e6:.2f}MB)  clean drops "
                      f"{kv['clean_drops']}  refills {kv['refills']}  "
                      f"prefetched {kv['prefetch_refills']}  "
                      f"kv-wait {kv['wait_seconds'] * 1e3:.1f}ms")
                print(f"kv-overlap: staged windows {ov['kv_stage_gets']}  "
                      f"ready-on-arrival {ov['kv_stage_hits']}  "
                      f"staged-wait {ov['kv_stage_wait_s'] * 1e3:.1f}ms")
            for i in range(min(args.batch, 2)):
                print(f"  request {i}: {gen[i][:16].tolist()} ...")
    print("offloaded serve OK")


if __name__ == "__main__":
    main()
