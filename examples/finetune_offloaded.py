"""End-to-end driver: fine-tune a ~100M-parameter LM with full SSD
offloading for a few hundred steps, ZeRO-Infinity baseline vs MemAscend.

Every piece of the paper's pipeline runs for real in this container:
weights+optimizer states live on the (raw-file) NVMe store, the host pool
streams compute weights per block with lookahead prefetch, gradients land
in the fp32 flat buffer, the fused bitwise check screens them, and the
subgroup-streamed CPU Adam updates SSD-resident state.

Policies come from the registry and execution runs through OffloadSession
(StreamPlan schedules + lookahead pipelining).

Run:  PYTHONPATH=src python examples/finetune_offloaded.py \
          [--steps 200] [--policy memascend|zero-infinity|memascend-bf16|both]
"""

import argparse
import tempfile
import time

import jax

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession, fmt_bytes
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

# ~100M params: 12 layers, d=512, ffn 2048, vocab 32k
CFG = ModelConfig(name="ft-100m", family="dense", n_layers=12, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_000)


def run(policy, steps: int, seq_len: int = 512, batch: int = 4) -> None:
    print(f"\n=== policy: {policy.name} (state dtype "
          f"{policy.adam.state_dtype}) ===")
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    with OffloadSession(model, policy) as s:
        print(f"params: {s.total_params / 1e6:.1f}M  "
              f"pool: {fmt_bytes(s.pool.pool_bytes)}  "
              f"flat buffer: {fmt_bytes(s.flat.nbytes)}  "
              f"lookahead: {s.lookahead}")
        dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                        batch=batch, seq_len=seq_len)
        t0 = time.time()
        for step in range(1, steps + 1):
            b = dl.next_batch()
            m = s.train_step(b["tokens"], b["labels"])
            if step % 20 == 0 or step == 1:
                tput = step * batch * seq_len / (time.time() - t0)
                print(f"step {step:4d}  loss {m['loss']:.4f}  "
                      f"scale {m['loss_scale']:.0f}  "
                      f"opt-io {fmt_bytes(m['optimizer_io_bytes'])}/step  "
                      f"fetch-wait {m['fetch_wait_s'] * 1e3:.0f}ms  "
                      f"{tput:.0f} tok/s")
        print(f"peak host memory: {fmt_bytes(s.tracker.peak_allocated)}")
        print(f"pool fragmentation: {s.pool.fragmentation():.1%}")
        print(f"SSD io: written {fmt_bytes(s.store.stats.bytes_written)}, "
              f"read {fmt_bytes(s.store.stats.bytes_read)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--policy", default="both",
                    choices=OffloadPolicy.names() + ["both"])
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    names = (["zero-infinity", "memascend"] if args.policy == "both"
             else [args.policy])
    with tempfile.TemporaryDirectory(prefix="ft_offload_") as root:
        for i, name in enumerate(names):
            policy = (OffloadPolicy.preset(name)
                      .with_store(f"{root}/{i}")
                      .with_adam(lr=args.lr).build())
            run(policy, args.steps)


if __name__ == "__main__":
    main()
