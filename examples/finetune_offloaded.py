"""End-to-end driver: fine-tune a ~100M-parameter LM with full SSD
offloading for a few hundred steps, ZeRO-Infinity baseline vs MemAscend.

Every piece of the paper's pipeline runs for real in this container:
weights+optimizer states live on the (raw-file) NVMe store, the host pool
streams compute weights per block, gradients land in the fp32 flat buffer,
the fused bitwise check screens them, and the subgroup-streamed CPU Adam
updates SSD-resident state.

Run:  PYTHONPATH=src python examples/finetune_offloaded.py \
          [--steps 200] [--policy memascend|zero-infinity|both] [--bf16-opt]
"""

import argparse
import tempfile
import time

import jax

from repro.configs.base import ModelConfig
from repro.core import (OffloadedTrainer, fmt_bytes, memascend_policy,
                        zero_infinity_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

# ~100M params: 12 layers, d=512, ffn 2048, vocab 32k
CFG = ModelConfig(name="ft-100m", family="dense", n_layers=12, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_000)


def run(policy, steps: int, seq_len: int = 512, batch: int = 4) -> None:
    print(f"\n=== policy: {policy.name} (state dtype "
          f"{policy.adam.state_dtype}) ===")
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    trainer = OffloadedTrainer(model, policy)
    print(f"params: {trainer.total_params / 1e6:.1f}M  "
          f"pool: {fmt_bytes(trainer.pool.pool_bytes)}  "
          f"flat buffer: {fmt_bytes(trainer.flat.nbytes)}")
    dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                    batch=batch, seq_len=seq_len)
    t0 = time.time()
    for step in range(1, steps + 1):
        b = dl.next_batch()
        m = trainer.train_step(b["tokens"], b["labels"])
        if step % 20 == 0 or step == 1:
            tput = step * batch * seq_len / (time.time() - t0)
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"scale {m['loss_scale']:.0f}  "
                  f"opt-io {fmt_bytes(m['optimizer_io_bytes'])}/step  "
                  f"{tput:.0f} tok/s")
    print(f"peak host memory: {fmt_bytes(trainer.tracker.peak_allocated)}")
    print(f"pool fragmentation: {trainer.pool.fragmentation():.1%}")
    print(f"SSD io: written {fmt_bytes(trainer.store.stats.bytes_written)}, "
          f"read {fmt_bytes(trainer.store.stats.bytes_read)}")
    trainer.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--policy", default="both",
                    choices=["memascend", "zero-infinity", "both"])
    ap.add_argument("--bf16-opt", action="store_true")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory(prefix="ft_offload_") as root:
        if args.policy in ("zero-infinity", "both"):
            run(zero_infinity_policy(root + "/z", lr=1e-3), args.steps)
        if args.policy in ("memascend", "both"):
            run(memascend_policy(root + "/m", lr=1e-3,
                                 bf16_optimizer=args.bf16_opt), args.steps)


if __name__ == "__main__":
    main()
