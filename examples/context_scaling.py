"""Paper §V-B story, runnable: how reclaimed host memory converts into
context length (and batch) under a fixed memory cap.

Run:  PYTHONPATH=src python examples/context_scaling.py [--limit-gib 128]
"""

import argparse

from benchmarks.memory_model import (GIB, estimate_peak, max_batch_under,
                                     max_context_under)
from repro.configs import ALL_MODELS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--limit-gib", type=float, default=128.0)
    ap.add_argument("--model", default="qwen2.5-7b",
                    choices=sorted(ALL_MODELS))
    args = ap.parse_args()
    cfg = ALL_MODELS[args.model]
    limit = int(args.limit_gib * GIB)

    print(f"{cfg.name}: peak host memory vs context (batch 1, 2 ranks)")
    print(f"{'context':>9} | {'ZeRO-Infinity':>14} | {'MemAscend':>10}")
    for ctx in (4096, 16384, 32768, 65536, 131072):
        b = estimate_peak(cfg, memascend=False, ctx=ctx, batch=1).total / GIB
        m = estimate_peak(cfg, memascend=True, ctx=ctx, batch=1).total / GIB
        print(f"{ctx:>9} | {b:>11.1f}GiB | {m:>7.1f}GiB")

    cb = max_context_under(cfg, limit, memascend=False, batch=1)
    cm = max_context_under(cfg, limit, memascend=True, batch=1)
    bb = max_batch_under(cfg, limit, memascend=False)
    bm = max_batch_under(cfg, limit, memascend=True)
    print(f"\nunder {args.limit_gib:.0f} GiB: max context "
          f"{cb} -> {cm}; max batch (ctx 4096) {bb} -> {bm}")
    print("paper (qwen2.5-7b, 128 GiB): context 16,384 -> 131,072; "
          "batch 4 -> 32")


if __name__ == "__main__":
    main()
