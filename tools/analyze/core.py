"""Shared infrastructure: source parsing, annotation extraction, the
class/function index, receiver-type resolution, and the lock-state walk
used by the lock-discipline and no-blocking-under-lock checkers."""

from __future__ import annotations

import ast
import contextlib
import hashlib
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

# The documented pipeline roles (docs/ARCHITECTURE.md thread contracts).
ROLES = frozenset({
    "executor",        # the user's compute/drive thread
    "h2d-worker",      # SerialWorker "offload-h2d" staging thread
    "writer",          # SerialWorker "offload-gradwrite" thread
    "optim-worker",    # SerialWorker "offload-optim" thread
    "optim-prefetch",  # SerialWorker "offload-optim-prefetch" thread
    "store-worker",    # store aio / direct-nvme pool threads
    "any",             # thread-safe: callable from every role
})

CHECKERS = ("lock-discipline", "lock-blocking", "thread-affinity",
            "resource-lifecycle", "annotation")

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_THREAD_RE = re.compile(r"#\s*thread:\s*([A-Za-z][\w, -]*)")
_HOLDS_RE = re.compile(r"#\s*analyze:\s*holds\(([A-Za-z_]\w*)\)")
_BLOCKING_RE = re.compile(r"#\s*analyze:\s*blocking\b")
_PRESHARE_RE = re.compile(r"#\s*analyze:\s*pre-share\b")
_IGNORE_RE = re.compile(r"#\s*analyze:\s*ignore(?:\[([\w\-, ]+)\])?")

_LOCK_FACTORIES = {"Lock", "RLock", "Condition"}


@dataclass(frozen=True)
class Finding:
    path: str          # repo-relative, forward slashes
    line: int
    checker: str
    symbol: str        # "Class.method" / "function" / "<module>"
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: [{self.checker}] "
                f"{self.symbol}: {self.message}")

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used by the committed baseline, so a
        baselined finding survives unrelated edits above it."""
        digest = hashlib.sha1(self.message.encode()).hexdigest()[:12]
        return f"{self.path}::{self.checker}::{self.symbol}::{digest}"


@dataclass
class FunctionInfo:
    node: ast.AST                       # FunctionDef | AsyncFunctionDef
    module: SourceModule
    qualname: str
    cls: ClassInfo | None = None
    roles: frozenset[str] | None = None
    holds: set[str] = field(default_factory=set)
    blocking: bool = False
    pre_share: bool = False

    @property
    def name(self) -> str:
        return self.node.name


@dataclass
class ClassInfo:
    node: ast.ClassDef
    module: SourceModule
    name: str
    bases: list[str] = field(default_factory=list)
    lock_attrs: set[str] = field(default_factory=set)
    guarded: dict[str, str] = field(default_factory=dict)  # field -> lock
    attr_types: dict[str, str] = field(default_factory=dict)
    methods: dict[str, FunctionInfo] = field(default_factory=dict)


def _comment_map(source: str) -> dict[int, str]:
    out: dict[int, str] = {}
    with contextlib.suppress(tokenize.TokenError):
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    return out


def _first_class_name(node: ast.AST | None) -> str | None:
    """First plain Name inside an annotation — resolves e.g.
    ``SpillableKVCache | None`` to ``SpillableKVCache``."""
    if node is None:
        return None
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            return sub.id
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            # string annotation: "ClassName | None"
            head = re.match(r"[A-Za-z_]\w*", sub.value)
            if head:
                return head.group(0)
    return None


def attr_chain(node: ast.AST) -> str | None:
    """Dotted source text of a Name/Attribute chain (``self.store``) or
    None if the expression is anything more complex."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class SourceModule:
    """One parsed file: AST + comments + annotations + suppressions."""

    def __init__(self, path: Path, rel: str) -> None:
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.tree = ast.parse(self.source, filename=str(path))
        self.comments = _comment_map(self.source)
        self.suppress: dict[int, set[str]] = {}
        for line, text in self.comments.items():
            m = _IGNORE_RE.search(text)
            if m:
                ids = m.group(1)
                self.suppress[line] = (
                    {s.strip() for s in ids.split(",") if s.strip()}
                    if ids else set(CHECKERS))
        self.classes: dict[str, ClassInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.guarded_registry: dict[str, str] = {}  # "Cls.field" -> lock
        self.annotation_errors: list[Finding] = []
        self._index()

    # -- annotation extraction ------------------------------------------------

    def suppressed(self, line: int, checker: str) -> bool:
        return checker in self.suppress.get(line, ())

    def _def_comments(self, node: ast.AST) -> str:
        """Comments that can annotate a def: trailing on the def line plus
        any comment-only lines directly above it (or above its first
        decorator)."""
        first = min([node.lineno]
                    + [d.lineno for d in getattr(node, "decorator_list", [])])
        texts = [self.comments.get(node.lineno, "")]
        line = first - 1
        while line in self.comments:
            texts.append(self.comments[line])
            line -= 1
        return "\n".join(texts)

    def _lines_of(self, node: ast.AST) -> str:
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        return "\n".join(self.comments.get(i, "")
                         for i in range(node.lineno, end + 1))

    def _index(self) -> None:
        for stmt in self.tree.body:
            if isinstance(stmt, ast.ClassDef):
                self._index_class(stmt)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[stmt.name] = self._function_info(stmt, None)
            elif isinstance(stmt, ast.Assign):
                self._maybe_registry(stmt)

    def _maybe_registry(self, stmt: ast.Assign) -> None:
        # module-level  GUARDED_BY = {"Cls.field": "_lock", ...}
        if not (len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and stmt.targets[0].id == "GUARDED_BY"
                and isinstance(stmt.value, ast.Dict)):
            return
        for k, v in zip(stmt.value.keys, stmt.value.values, strict=True):
            if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)):
                self.guarded_registry[k.value] = v.value

    def _function_info(self, node: ast.AST,
                       cls: ClassInfo | None) -> FunctionInfo:
        text = self._def_comments(node)
        qual = f"{cls.name}.{node.name}" if cls else node.name
        info = FunctionInfo(node=node, module=self, qualname=qual, cls=cls)
        m = _THREAD_RE.search(text)
        if m:
            roles = {r.strip() for r in m.group(1).split(",") if r.strip()}
            bad = roles - ROLES
            if bad:
                self.annotation_errors.append(Finding(
                    self.rel, node.lineno, "annotation", qual,
                    f"unknown thread role(s) {sorted(bad)}; valid: "
                    f"{sorted(ROLES)}"))
            info.roles = frozenset(roles & ROLES) or None
        for m in _HOLDS_RE.finditer(text):
            info.holds.add(m.group(1))
        info.blocking = bool(_BLOCKING_RE.search(text))
        info.pre_share = bool(_PRESHARE_RE.search(text))
        return info

    def _index_class(self, node: ast.ClassDef) -> None:
        ci = ClassInfo(node=node, module=self, name=node.name,
                       bases=[b for b in (attr_chain(x) for x in node.bases)
                              if b])
        self.classes[node.name] = ci
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ci.methods[stmt.name] = self._function_info(stmt, ci)
                self._scan_self_assigns(ci, stmt)
            elif isinstance(stmt, ast.AnnAssign) and isinstance(
                    stmt.target, ast.Name):
                # class-level annotated field (dataclass style)
                ann = attr_chain(stmt.annotation)
                if ann and ann.split(".")[-1] in _LOCK_FACTORIES:
                    ci.lock_attrs.add(stmt.target.id)
                t = _first_class_name(stmt.annotation)
                if t:
                    ci.attr_types.setdefault(stmt.target.id, t)
                self._maybe_guarded(ci, stmt, stmt.target.id)

    def _scan_self_assigns(self, ci: ClassInfo, fn: ast.AST) -> None:
        for stmt in ast.walk(fn):
            target: ast.expr | None = None
            value: ast.expr | None = None
            annotation: ast.expr | None = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                target, value = stmt.targets[0], stmt.value
            elif isinstance(stmt, ast.AnnAssign):
                target, value, annotation = (stmt.target, stmt.value,
                                             stmt.annotation)
            if not (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id == "self"):
                continue
            attr = target.attr
            # lock discovery:  self._lock = threading.Lock()/Condition(..)
            chain = (attr_chain(value.func)
                     if isinstance(value, ast.Call) else None)
            if chain and chain.split(".")[-1] in _LOCK_FACTORIES:
                ci.lock_attrs.add(attr)
            # attr type:  self.pool = PinnedBufferPool(...)   or
            #             self.kv: SpillableKVCache | None = None
            if isinstance(value, ast.Call) and chain and "." not in chain:
                ci.attr_types.setdefault(attr, chain)
            t = _first_class_name(annotation)
            if t:
                ci.attr_types.setdefault(attr, t)
            self._maybe_guarded(ci, stmt, attr)

    def _maybe_guarded(self, ci: ClassInfo, stmt: ast.AST,
                       attr: str) -> None:
        m = _GUARDED_RE.search(self._lines_of(stmt))
        if m:
            ci.guarded[attr] = m.group(1)


class Project:
    """All modules under the analyzed roots, plus cross-module lookups."""

    def __init__(self, modules: list[SourceModule]) -> None:
        self.modules = modules
        self.class_index: dict[str, ClassInfo] = {}
        self.function_index: dict[str, FunctionInfo] = {}
        for mod in modules:
            for ci in mod.classes.values():
                self.class_index.setdefault(ci.name, ci)
            for fi in mod.functions.values():
                self.function_index.setdefault(fi.qualname, fi)
        self._apply_registries()

    @classmethod
    def load(cls, paths: list[Path], root: Path) -> Project:
        files: list[Path] = []
        for p in paths:
            if p.is_dir():
                files.extend(sorted(p.rglob("*.py")))
            else:
                files.append(p)
        modules = []
        for f in files:
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            modules.append(SourceModule(f, rel))
        return cls(modules)

    def _apply_registries(self) -> None:
        for mod in self.modules:
            for key, lock in mod.guarded_registry.items():
                cls_name, _, attr = key.partition(".")
                ci = mod.classes.get(cls_name) or self.class_index.get(
                    cls_name)
                if ci is not None and attr:
                    ci.guarded[attr] = lock
                else:
                    mod.annotation_errors.append(Finding(
                        mod.rel, 1, "annotation", "<module>",
                        f"GUARDED_BY entry {key!r} names an unknown class"))

    # -- lookups --------------------------------------------------------------

    def resolve_class(self, name: str | None) -> ClassInfo | None:
        return self.class_index.get(name) if name else None

    def lookup_method(self, ci: ClassInfo | None,
                      name: str) -> FunctionInfo | None:
        seen = set()
        while ci is not None and ci.name not in seen:
            seen.add(ci.name)
            if name in ci.methods:
                return ci.methods[name]
            ci = next((self.class_index[b] for b in ci.bases
                       if b in self.class_index), None)
        return None

    def class_guarded(self, ci: ClassInfo) -> dict[str, str]:
        """Guarded fields including ones inherited from known bases."""
        out: dict[str, str] = {}
        chain, seen = [], set()
        cur: ClassInfo | None = ci
        while cur is not None and cur.name not in seen:
            seen.add(cur.name)
            chain.append(cur)
            cur = next((self.class_index[b] for b in cur.bases
                        if b in self.class_index), None)
        for c in reversed(chain):
            out.update(c.guarded)
        return out

    def class_locks(self, ci: ClassInfo) -> set[str]:
        out: set[str] = set()
        chain, seen = [ci], {ci.name}
        cur = ci
        while True:
            nxt = next((self.class_index[b] for b in cur.bases
                        if b in self.class_index
                        and b not in seen), None)
            if nxt is None:
                break
            seen.add(nxt.name)
            chain.append(nxt)
            cur = nxt
        for c in chain:
            out |= c.lock_attrs
        return out


# -- execution-order lock-state walk ------------------------------------------

class LockWalk:
    """Walks a function body in source order, tracking which of the given
    ``self.<lock>`` locks are held, and invoking ``visit(node, held)`` for
    every expression node.  Approximation: branches of if/try are walked
    sequentially with shared state — explicit ``self.X.release()`` /
    ``.acquire()`` calls toggle the held set, which is exactly the pattern
    ``SpillableKVCache._spill`` uses to drop the lock around a store
    write."""

    def __init__(self, locks: set[str], visit) -> None:
        self.locks = locks
        self.visit = visit
        self.held: set[str] = set()

    def _lock_of(self, node: ast.AST) -> str | None:
        chain = attr_chain(node)
        if chain and chain.startswith("self."):
            attr = chain.split(".", 1)[1]
            if attr in self.locks:
                return attr
        return None

    def run(self, fn: ast.AST, initially: set[str]) -> None:
        self.held = set(initially)
        self._stmts(fn.body)

    def _stmts(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.With):
            entered: list[str] = []
            for item in stmt.items:
                self._expr(item.context_expr)
                lock = self._lock_of(item.context_expr)
                if lock is not None:
                    entered.append(lock)
            snapshot = set(self.held)
            self.held.update(entered)
            self._stmts(stmt.body)
            self.held = snapshot
        elif isinstance(stmt, ast.Try):
            self._stmts(stmt.body)
            for h in stmt.handlers:
                self._stmts(h.body)
            self._stmts(stmt.orelse)
            self._stmts(stmt.finalbody)
        elif isinstance(stmt, (ast.If, ast.For, ast.While)):
            for f in stmt._fields:
                v = getattr(stmt, f)
                if isinstance(v, list) and v and isinstance(v[0], ast.stmt):
                    self._stmts(v)
                elif isinstance(v, ast.expr):
                    self._expr(v)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass        # nested defs run later, on an unknown thread
        else:
            self._expr(stmt)

    def _expr(self, node: ast.AST) -> None:
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and chain.startswith("self."):
                parts = chain.split(".")
                if len(parts) == 3 and parts[1] in self.locks:
                    if parts[2] == "release":
                        self.visit(node, self.held)
                        self.held.discard(parts[1])
                        return
                    if parts[2] == "acquire":
                        self.visit(node, self.held)
                        self.held.add(parts[1])
                        return
        for child in ast.iter_child_nodes(node):
            self._expr(child)
        self.visit(node, self.held)


def run_checkers(project: Project) -> list[Finding]:
    from . import affinity, lifecycle, lock_blocking, lock_discipline
    findings: list[Finding] = []
    for mod in project.modules:
        findings.extend(mod.annotation_errors)
    findings.extend(lock_discipline.check(project))
    findings.extend(lock_blocking.check(project))
    findings.extend(affinity.check(project))
    findings.extend(lifecycle.check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.checker))
    return findings
