"""Checker 4 — resource lifecycle: a pool/slot checkout
(``x = <obj>.acquire(...)``, ``<obj>.claim(...)``, ``<obj>.alloc(...)``,
or ``ensure_page(..., pin=True)``) must reach its release on every
exception path before the next statement that can raise.

A checkout is considered safe when, scanning forward in execution order
(through the enclosing blocks), one of these happens before any
may-raise statement:

* a release call on the checkout (``x.release()``, ``x.unpin(...)``, …)
* a ``try`` whose handler or ``finally`` contains a release-family call
  (presence-based: the handler may release through a different alias,
  e.g. a claims list)
* the value escapes — returned, yielded, stored into an attribute or
  container, aliased, or passed to another call (ownership moved; the
  receiver's lifecycle is its own checker case)

Checkouts already inside a ``try`` whose handler/finally releases are
covered from the start.  ``with`` context managers are inherently safe.
Lock ``acquire()`` calls are the lock-discipline checkers' business and
are excluded here."""

from __future__ import annotations

import ast

from .core import Finding, Project, attr_chain

_PRODUCER_ATTRS = {"acquire", "claim", "alloc"}
_RELEASE_ATTRS = {"release", "release_all", "unpin", "free", "close",
                  "shutdown", "drain"}
_NO_RAISE_CALLS = {
    "time.perf_counter", "time.monotonic", "time.time",
    "len", "int", "float", "bool", "str", "repr", "min", "max",
    "isinstance", "sorted", "list", "dict", "set", "tuple", "range",
    "enumerate", "zip", "id", "getattr",
}


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules:
        infos = list(mod.functions.values())
        for ci in mod.classes.values():
            infos.extend(ci.methods.values())
        for fi in infos:
            locks = (project.class_locks(fi.cls)
                     if fi.cls is not None else set())
            findings.extend(_check_fn(mod, fi, locks))
    return findings


def _is_lockish(recv: str, locks: set[str]) -> bool:
    last = recv.split(".")[-1]
    return (last in locks or "lock" in last.lower()
            or last.endswith("_cv") or last == "_cv"
            or last.endswith("cond"))


def _producer_call(node: ast.AST, locks: set[str]) -> str | None:
    """Returns a short description if ``node`` is a tracked checkout."""
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)):
        return None
    attr = node.func.attr
    chain = attr_chain(node.func)
    recv = chain.rsplit(".", 1)[0] if chain else ""
    if attr.lstrip("_") in _PRODUCER_ATTRS:
        if recv and _is_lockish(recv, locks):
            return None
        return f"{chain or attr}()"
    if attr == "ensure_page" and any(
            kw.arg == "pin" and isinstance(kw.value, ast.Constant)
            and kw.value.value is True for kw in node.keywords):
        return f"{chain or attr}(pin=True)"
    return None


def _try_releases(stmt: ast.Try) -> bool:
    blocks = [b for h in stmt.handlers for b in h.body] + stmt.finalbody
    for s in blocks:
        for node in ast.walk(s):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _RELEASE_ATTRS):
                return True
    return False


def _releases_name(stmt: ast.stmt, name: str | None) -> bool:
    for node in ast.walk(stmt):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _RELEASE_ATTRS):
            if name is None:
                return True
            recv = attr_chain(node.func.value)
            if recv == name:
                return True
    return False


def _escapes(stmt: ast.stmt, name: str | None) -> bool:
    if name is None:
        return False

    def mentions(node: ast.AST | None) -> bool:
        # A mention in receiver position (``buf.view(...)``) is use, not
        # escape — only args, targets-of-store, returns etc. move
        # ownership.
        if node is None:
            return False
        receiver_pos: set[int] = set()
        for c in ast.walk(node):
            if isinstance(c, ast.Call):
                for n in ast.walk(c.func):
                    receiver_pos.add(id(n))
        return any(isinstance(n, ast.Name) and n.id == name
                   and id(n) not in receiver_pos
                   for n in ast.walk(node))

    for node in ast.walk(stmt):
        if isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom,
                             ast.Raise)):
            if mentions(node):
                return True
        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if mentions(node.value):
                return True
        elif isinstance(node, ast.Call) and (
                any(mentions(a) for a in node.args)
                or any(mentions(kw.value) for kw in node.keywords)):
            return True
    return False


def _may_raise(stmt: ast.stmt) -> bool:
    if isinstance(stmt, (ast.Raise, ast.Assert)):
        return True
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain in _NO_RAISE_CALLS:
                continue
            return True
        if isinstance(node, (ast.Raise, ast.Assert)):
            return True
    return False


def _check_fn(mod, fi, locks) -> list[Finding]:
    out: list[Finding] = []

    def scan_block(stmts: list[ast.stmt], protected: bool,
                   continuation: list[list[ast.stmt]]) -> None:
        for i, stmt in enumerate(stmts):
            rest = stmts[i + 1:]
            producer, name = _stmt_producer(stmt)
            if producer is not None and not protected:
                _analyze(stmt, producer, name, rest, continuation)
            for body, prot in _child_blocks(stmt, protected):
                scan_block(body, prot, [rest] + continuation)

    def _stmt_producer(stmt: ast.stmt):
        value: ast.expr | None = None
        name: str | None = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            t = stmt.targets[0]
            value = stmt.value
            if isinstance(t, ast.Name):
                name = t.id
            elif (isinstance(t, ast.Tuple) and t.elts
                    and isinstance(t.elts[0], ast.Name)):
                name = t.elts[0].id
            else:
                return None, None    # self.x = acquire(): stored, owned
        elif (isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)):
            value, name = stmt.value, stmt.target.id
        elif isinstance(stmt, ast.Expr):
            value = stmt.value
        else:
            return None, None
        desc = _producer_call(value, locks) if value is not None else None
        return desc, (name if desc else None)

    def _analyze(stmt, desc, name, rest, continuation) -> None:
        if mod.suppressed(stmt.lineno, "resource-lifecycle"):
            return
        following = list(rest)
        for block in continuation:
            following.extend(block)
        for nxt in following:
            if isinstance(nxt, ast.Try) and _try_releases(nxt):
                return
            if _releases_name(nxt, name):
                return
            if _escapes(nxt, name):
                return
            if _may_raise(nxt):
                out.append(Finding(
                    mod.rel, stmt.lineno, "resource-lifecycle",
                    fi.qualname,
                    f"checkout {desc} can leak: "
                    f"'{ast.unparse(nxt)[:60]}' (line {nxt.lineno}) may "
                    f"raise before any release/try-protection"))
                return
        out.append(Finding(
            mod.rel, stmt.lineno, "resource-lifecycle", fi.qualname,
            f"checkout {desc} is never released, escaped, or "
            f"try-protected on this path"))

    def _child_blocks(stmt: ast.stmt, protected: bool):
        if isinstance(stmt, ast.Try):
            prot = protected or _try_releases(stmt)
            yield stmt.body, prot
            for h in stmt.handlers:
                yield h.body, protected
            yield stmt.orelse, prot
            yield stmt.finalbody, protected
        elif isinstance(stmt, (ast.If, ast.For, ast.While)):
            yield stmt.body, protected
            yield stmt.orelse, protected
        elif isinstance(stmt, ast.With):
            yield stmt.body, protected

    scan_block(fi.node.body, False, [])
    return out
