"""CLI: ``python -m tools.analyze [paths...]``.

Exit status is 0 when every finding is either inline-suppressed
(``# analyze: ignore[checker]``) or listed in the committed baseline
(``tools/analyze/baseline.json``), 1 otherwise.  ``--write-baseline``
refreshes the baseline from the current findings; ``--no-baseline``
ignores it (shows the analyzer's raw view)."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import Project, run_checkers

_HERE = Path(__file__).resolve().parent
DEFAULT_BASELINE = _HERE / "baseline.json"


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="Concurrency-contract static analyzer "
                    "(lock discipline, blocking-under-lock, thread "
                    "affinity, resource lifecycle).")
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or directories to analyze "
                             "(default: src/repro)")
    parser.add_argument("--root", default=".",
                        help="repo root for relative paths in output")
    parser.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                        help="baseline file of accepted finding "
                             "fingerprints")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report findings even if baselined")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept all current findings into the "
                             "baseline file")
    args = parser.parse_args(argv)

    root = Path(args.root)
    project = Project.load([Path(p) for p in args.paths], root)
    findings = run_checkers(project)

    if args.write_baseline:
        args.baseline.write_text(json.dumps(
            {"version": 1,
             "findings": sorted(f.fingerprint for f in findings)},
            indent=2) + "\n")
        print(f"wrote {len(findings)} fingerprint(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    fresh = [f for f in findings if f.fingerprint not in baseline]
    baselined = len(findings) - len(fresh)

    for f in fresh:
        print(f.format())
    n_files = len(project.modules)
    tail = f" ({baselined} baselined)" if baselined else ""
    print(f"tools.analyze: {len(fresh)} finding(s) in {n_files} "
          f"file(s){tail}", file=sys.stderr)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
