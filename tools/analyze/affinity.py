"""Checker 3 — thread affinity: a function annotated ``# thread: r1, r2``
may only be called (directly, or transitively through unannotated
project functions) from functions whose roles are a subset of
``{r1, r2}``.  ``# thread: any`` marks a function callable from every
role (fully locked / thread-safe).

Receivers are resolved through ``self``, annotated parameters, annotated
or constructor-assigned instance attributes, and simple local
assignments — enough for the pipeline's call shapes
(``state.kv.gather_window(...)``, ``self.swapper.claim(...)``).

References that are *submitted* rather than called
(``worker.submit(self._fn)``, ``functools.partial(fn, ...)``) are not
call edges: the submission target's own annotation covers the body that
eventually runs."""

from __future__ import annotations

import ast

from .core import ClassInfo, Finding, FunctionInfo, Project


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules:
        infos = list(mod.functions.values())
        for ci in mod.classes.values():
            infos.extend(ci.methods.values())
        for fi in infos:
            if fi.roles and "any" not in fi.roles:
                findings.extend(_check_root(project, fi))
    return findings


def _check_root(project: Project, root: FunctionInfo) -> list[Finding]:
    out: list[Finding] = []
    visited: set[str] = {root.qualname}
    stack = [root]
    while stack:
        fi = stack.pop()
        for node, callee in _calls_in(project, fi):
            if callee is None or callee.qualname in visited:
                continue
            if callee.roles is None:
                # unannotated project function: the root's roles flow
                # through it — keep walking its body
                visited.add(callee.qualname)
                stack.append(callee)
                continue
            if "any" in callee.roles or root.roles <= callee.roles:
                continue
            if fi.module.suppressed(node.lineno, "thread-affinity"):
                continue
            via = ("" if fi is root
                   else f" (reached via {fi.qualname})")
            out.append(Finding(
                fi.module.rel, node.lineno, "thread-affinity",
                root.qualname,
                f"calls {callee.qualname} (thread: "
                f"{', '.join(sorted(callee.roles))}) from a context that "
                f"may run on {', '.join(sorted(root.roles))}{via}"))
    return out


def _calls_in(project: Project, fi: FunctionInfo):
    """(call-node, resolved FunctionInfo|None) for every direct call in
    the body, not descending into nested defs/lambdas (those run on
    whatever thread eventually invokes them)."""
    env = _build_env(project, fi)
    for call in _toplevel_calls(fi.node):
        yield call, _resolve(project, fi, env, call.func)


def _toplevel_calls(fn: ast.AST):
    todo = list(ast.iter_child_nodes(fn))
    while todo:
        node = todo.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call):
            yield node
        todo.extend(ast.iter_child_nodes(node))


def _build_env(project: Project,
               fi: FunctionInfo) -> dict[str, ClassInfo]:
    env: dict[str, ClassInfo] = {}
    if fi.cls is not None:
        env["self"] = fi.cls
    args = fi.node.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        ci = _ann_class(project, a.annotation)
        if ci is not None:
            env[a.arg] = ci
    # simple local inference: x = ClassName(...)  /  x = self.attr
    for stmt in ast.walk(fi.node):
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        name, value = stmt.targets[0].id, stmt.value
        if (isinstance(value, ast.Call)
                and isinstance(value.func, ast.Name)):
            ci = project.resolve_class(value.func.id)
            if ci is not None:
                env.setdefault(name, ci)
        elif isinstance(value, ast.Attribute):
            ci = _expr_class(project, env, value)
            if ci is not None:
                env.setdefault(name, ci)
    return env


def _ann_class(project: Project, ann: ast.AST | None) -> ClassInfo | None:
    from .core import _first_class_name
    return project.resolve_class(_first_class_name(ann))


def _attr_class(project: Project, ci: ClassInfo,
                attr: str) -> ClassInfo | None:
    seen: set[str] = set()
    cur: ClassInfo | None = ci
    while cur is not None and cur.name not in seen:
        seen.add(cur.name)
        if attr in cur.attr_types:
            return project.resolve_class(cur.attr_types[attr])
        cur = next((project.class_index[b] for b in cur.bases
                    if b in project.class_index), None)
    return None


def _expr_class(project: Project, env: dict[str, ClassInfo],
                node: ast.AST) -> ClassInfo | None:
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.Attribute):
        base = _expr_class(project, env, node.value)
        if base is not None:
            return _attr_class(project, base, node.attr)
    return None


def _resolve(project: Project, fi: FunctionInfo,
             env: dict[str, ClassInfo],
             func: ast.AST) -> FunctionInfo | None:
    if isinstance(func, ast.Name):
        if project.resolve_class(func.id) is not None:
            return None                       # constructor
        return fi.module.functions.get(func.id)
    if isinstance(func, ast.Attribute):
        recv = _expr_class(project, env, func.value)
        if recv is not None:
            return project.lookup_method(recv, func.attr)
    return None
