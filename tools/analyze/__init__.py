"""Concurrency-contract static analyzer for the offload pipeline.

The pipeline's safety rules — which fields a lock guards, which thread a
function may run on, what must never block while a lock is held, and which
resources must reach ``release()`` on every path — used to live only in
docstrings.  This package turns them into machine-checked annotations:

``# guarded-by: _lock``
    trailing a ``self.field = ...`` assignment: the field may only be
    touched while ``self._lock`` is held.
``# thread: executor, h2d-worker``
    on a ``def`` line: the function only runs on those pipeline threads.
``# analyze: holds(_lock)``
    on a ``def`` line: the function is always entered with the lock held.
``# analyze: blocking``
    on a ``def`` line: calling this function can block (checker 2 treats
    a call to it like store I/O).
``# analyze: pre-share``
    on a ``def`` line: runs before the object is visible to other
    threads (construction helpers) — exempt from lock discipline.
``# analyze: ignore[checker-id]``
    trailing any line: suppress findings from that checker on that line.

See docs/ANALYSIS.md for the full vocabulary and checker semantics.
Run with ``python -m tools.analyze src/repro``.
"""

from .core import Finding, Project, SourceModule, run_checkers

__all__ = ["Finding", "Project", "SourceModule", "run_checkers"]
