"""MUST-PASS — the shipped fix for historical race #2: the read is
issued inside a ``try`` whose handler returns the slot before
re-raising, and the counters move under the lock.  The lifecycle checker
accepts the checkout because the very next statement is a try whose
handler contains a release-family call; the discipline checker sees both
counter writes inside ``with self._lock``."""

import threading

GUARDED_BY = {"PrefetcherFixed.pending": "_lock"}


class PrefetcherFixed:
    def __init__(self, pool, store):
        self.pool = pool
        self.store = store
        self._lock = threading.Lock()
        self.in_flight = 0       # guarded-by: _lock
        self.pending = 0         # registry-declared: see GUARDED_BY above

    def prefetch(self, key, nbytes):
        buf = self.pool.acquire("w", nbytes)
        try:
            data = self.store.read(key)
            buf.write(data)
        except Exception:
            buf.release()
            raise
        with self._lock:
            self.in_flight += 1
            self.pending += 1
        return buf
