"""MUST-PASS — the holds contract satisfied: the caller wraps the
holds-annotated callee in ``with self._lock``, and the callee's own
guarded access is covered by its starting lock set."""

import threading


class LedgerOk:
    def __init__(self):
        self._lock = threading.Lock()
        self._total = 0      # guarded-by: _lock

    def _add_locked(self, n):  # analyze: holds(_lock)
        self._total += n

    def record(self, n):
        with self._lock:
            self._add_locked(n)
