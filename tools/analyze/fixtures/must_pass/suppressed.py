"""MUST-PASS — the suppression syntax: every line here would flag its
checker and is deliberately silenced with a line-scoped, checker-scoped
``# analyze: ignore[checker-id]``.  A suppression for checker X must not
leak to checker Y: the lifecycle suppression below still leaves the
unguarded counter visible to lock-discipline, which has its own."""

import threading


class Suppressed:
    def __init__(self, pool, store):
        self.pool = pool
        self.store = store
        self._lock = threading.Lock()
        self.in_flight = 0       # guarded-by: _lock

    def spill(self, key, page):
        with self._lock:
            self.store.write(key, page)  # analyze: ignore[lock-blocking]

    def prefetch(self, key, nbytes):
        buf = self.pool.acquire("w", nbytes)  # analyze: ignore[resource-lifecycle]
        data = self.store.read(key)
        buf.write(data)
        self.in_flight += 1                   # analyze: ignore[lock-discipline]
        return buf
