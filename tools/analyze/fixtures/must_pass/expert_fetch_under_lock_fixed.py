"""MUST-PASS — the expert-fetch-under-cache-lock race, fixed.

The refill parks the key in ``_in_transit`` under the lock, performs the
SSD read unlocked (other ensuring threads keep making progress), and
re-takes the lock to land the page; a concurrent fetch of the same key
waits on the cache's own condition — allowed — until the read settles.
Prefetch futures settle before the lock is taken.  This is the
discipline ``repro.core.paged.PagedResidency`` ships with.
"""

import threading


class ExpertCacheFixed:
    def __init__(self, store, pool):
        self._lock = threading.Condition(threading.Lock())
        self.store = store
        self._resident = {}
        self._spilled = set()
        self._in_transit = set()

    def fetch(self, key, view):
        with self._lock:
            while key in self._in_transit:
                self._lock.wait()            # own condition: not a finding
            if key not in self._spilled:
                self._resident[key] = view
                return view
            self._spilled.discard(key)
            self._in_transit.add(key)
        try:
            self.store.read(key, view)       # unlocked: pipeline keeps moving
        finally:
            with self._lock:
                self._in_transit.discard(key)
                self._lock.notify_all()
        with self._lock:
            self._resident[key] = view
            return view

    def wait_prefetch(self, key, fut):
        view = fut.result()                  # settle outside the lock
        with self._lock:
            self._resident[key] = view
            return view
