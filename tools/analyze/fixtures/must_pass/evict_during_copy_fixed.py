"""MUST-PASS — the shipped fix for historical race #1: park the page in
``_evicting`` (readers can still find it), drop the lock around the
store write, reacquire to clear the parking entry — exactly the shape
``SpillableKVCache._spill`` uses.  The lock-state walk tracks the
explicit ``release()``/``acquire()`` toggles, so the write happens with
no lock held and nothing flags."""

import threading


class EvictingCacheFixed:
    def __init__(self, store, pool):
        self._lock = threading.Lock()
        self.store = store
        self._pages = {}
        self._evicting = {}

    def spill(self, key):
        self._lock.acquire()
        page = self._pages.pop(key)
        self._evicting[key] = page       # readers still see the page
        self._lock.release()
        self.store.write(key, page)      # no lock held: fine
        self._lock.acquire()
        del self._evicting[key]
        self._lock.release()
        return page
