"""MUST-PASS — affinity shapes that must stay silent: role subsets,
``any``-annotated (thread-safe) callees, references that are *submitted*
rather than called, and nested completion callbacks (those run on
whichever thread lands them; their bodies are not call edges of the
enclosing function)."""


class GradWriterOk:
    def writer_loop(self):  # thread: writer
        self.append_chunk()              # {writer} subset of its roles
        self.locked_counter()            # any: callable from every role

    def append_chunk(self):  # thread: executor, writer
        pass

    def locked_counter(self):  # thread: any
        pass

    def hand_off(self, worker):  # thread: writer
        worker.submit(self.apply_update)     # a reference, not a call edge

    def commit_async(self, fut):  # thread: writer
        def _on_landed(_):
            self.apply_update()              # runs on the landing thread
        fut.add_done_callback(_on_landed)

    def apply_update(self):  # thread: executor
        pass
