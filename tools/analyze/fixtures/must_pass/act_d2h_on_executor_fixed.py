"""MUST-PASS — the fixed activation-checkpoint path: the executor hands
the blocking D2H to the writer thread as a *submitted reference* (not a
call edge — the callee's own annotation covers the body that eventually
runs) and only waits the returned future; the wait-side helper is
annotated for the executor, so the save/fetch pair stays silent."""


class CheckpointPathFixed:
    def save_checkpoint(self, worker):  # thread: executor
        self.pending = worker.submit(self._blocking_d2h)   # a reference

    def restore_checkpoint(self):  # thread: executor
        self._wait_staged()              # {executor} subset of its roles

    def _wait_staged(self):  # thread: executor, writer
        pass

    def _blocking_d2h(self):  # thread: writer
        pass
