"""MUST-FLAG — historical race #1 (PR 5): evict-during-copy.

The paged KV cache's first spill path wrote the dirty page to the store
while still holding the cache lock.  Every thread touching the cache
meanwhile — the H2D stager refilling a neighbouring page, the executor
appending a decode step — blocked behind a multi-millisecond SSD write,
and with the store's backpressure in the loop the executor could wait on
a writer that was waiting on the executor's own pinned slot.  The fix
parks the page in ``_evicting`` and drops the lock around the write:
see ``must_pass/evict_during_copy_fixed.py``.

Expected findings: 2 × lock-blocking.
"""

import threading


class EvictingCache:
    """Distilled buggy shape: synchronous store I/O under the cache lock."""

    def __init__(self, store, pool):
        self._lock = threading.Lock()
        self.store = store
        self._pages = {}

    def spill(self, key):
        with self._lock:
            page = self._pages.pop(key)
            self.store.write(key, page)      # must-flag: store I/O under lock
        return page

    def wait_flush(self, fut):
        with self._lock:
            return fut.result()              # must-flag: future wait under lock
