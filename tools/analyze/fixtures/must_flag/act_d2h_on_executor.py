"""MUST-FLAG — thread affinity: a blocking D2H on the executor thread
under full overlap.  The checkpoint save/restore pair calls the
device-to-host copy helper inline, but that helper belongs to the writer
thread (where the copy hides under the next block's compute) — running
it on the executor serializes the pipeline, which is exactly the stall
the overlap machinery exists to remove.

Expected findings: 2 × thread-affinity.
"""


class CheckpointPath:
    def save_checkpoint(self):  # thread: executor
        self._blocking_d2h()             # must-flag: writer-only callee

    def restore_checkpoint(self):  # thread: executor
        self._blocking_d2h()             # must-flag: writer-only callee

    def _blocking_d2h(self):  # thread: writer
        pass
