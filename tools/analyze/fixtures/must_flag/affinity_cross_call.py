"""MUST-FLAG — thread affinity: the gradient writer calling an
executor-only method, both directly and through an unannotated helper
(the call-graph walk flows the root's roles through helpers it reaches).

Expected findings: 2 × thread-affinity.
"""


class GradWriter:
    def writer_loop(self):  # thread: writer
        self.apply_update()              # must-flag: executor-only callee

    def writer_entry(self):  # thread: writer
        self._flush_helper()

    def _flush_helper(self):
        self.apply_update()              # must-flag: reached from writer_entry

    def apply_update(self):  # thread: executor
        pass
