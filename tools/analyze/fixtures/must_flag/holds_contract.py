"""MUST-FLAG — the ``# analyze: holds(_lock)`` companion rule: a
holds-annotated method called without its lock.  The annotation is a
precondition, not a suggestion — inside the callee the discipline walk
starts with the lock held, so the call sites carry the obligation.

Expected findings: 1 × lock-blocking.
"""

import threading


class Ledger:
    def __init__(self):
        self._lock = threading.Lock()
        self._total = 0      # guarded-by: _lock

    def _add_locked(self, n):  # analyze: holds(_lock)
        self._total += n

    def record(self, n):
        self._add_locked(n)              # must-flag: holds precondition unmet
