"""MUST-FLAG — annotation validation: a thread role outside the
documented pipeline vocabulary, and a ``GUARDED_BY`` registry entry
naming a class the analyzer cannot find (typo'd registrations must not
silently guard nothing).

Expected findings: 2 × annotation.
"""

GUARDED_BY = {"NoSuchClass.count": "_lock"}


def poll_device():  # thread: gpu-poller
    pass
