"""MUST-FLAG — historical race #2 (PR 5): mid-read pool oversubscription.

Prefetch checked a slot out of the pinned pool, then issued the store
read; when the issue raised (missing key, saturated aio queue) the slot
was never returned — repeated failures drained the pool and every later
``acquire`` wedged in the capacity wait.  The in-flight counter was also
bumped outside the lock, so the stale-read write guard could miss a
concurrent read entirely.  Fix shape:
``must_pass/pool_oversubscription_fixed.py``.

One counter is declared with a trailing ``# guarded-by:`` comment, the
other through the module-level ``GUARDED_BY`` registry, so this file
also pins both declaration syntaxes.

Expected findings: 1 × resource-lifecycle, 2 × lock-discipline.
"""

import threading

GUARDED_BY = {"Prefetcher.pending": "_lock"}


class Prefetcher:
    def __init__(self, pool, store):
        self.pool = pool
        self.store = store
        self._lock = threading.Lock()
        self.in_flight = 0       # guarded-by: _lock
        self.pending = 0         # registry-declared: see GUARDED_BY above

    def prefetch(self, key, nbytes):
        buf = self.pool.acquire("w", nbytes)     # must-flag: leaks if the
        data = self.store.read(key)              # read raises at issue time
        buf.write(data)
        self.in_flight += 1                      # must-flag: unguarded write
        self.pending += 1                        # must-flag: unguarded write
        return buf
