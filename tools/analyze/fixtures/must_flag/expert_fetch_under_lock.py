"""MUST-FLAG — expert-fetch-under-cache-lock race (PR 10 bug class).

A first cut of the expert page cache refilled a spilled expert page with
a synchronous SSD read while still holding the cache lock.  The staging
worker building the next unit's stacks and the executor trimming the
round both serialize on that lock, so a single multi-millisecond expert
read stalled the whole prestage pipeline — and with the store's
backpressure in the loop, the worker could wait on a read that was
waiting on a buffer only the worker's own release would free.  The fix
parks the key in ``_in_transit`` and drops the lock around the read: see
``must_pass/expert_fetch_under_lock_fixed.py``.

Expected findings: 2 x lock-blocking.
"""

import threading


class ExpertCache:
    """Distilled buggy shape: refill I/O and prefetch settle under the
    cache lock."""

    def __init__(self, store, pool):
        self._lock = threading.Lock()
        self.store = store
        self._resident = {}
        self._spilled = set()

    def fetch(self, key, view):
        with self._lock:
            if key in self._spilled:
                self.store.read(key, view)   # must-flag: SSD read under lock
                self._spilled.discard(key)
            self._resident[key] = view
            return view

    def wait_prefetch(self, key, fut):
        with self._lock:
            view = fut.result()              # must-flag: future wait under lock
            self._resident[key] = view
            return view
