"""Checker 2 — no blocking under lock: while a ``threading.Lock`` /
``Condition`` attribute of the class is held, the walk forbids

* store I/O — ``*.store.read/write/read_new``, ``os.pread/pwrite/fsync``
* waiting on futures/threads — ``.result()``, ``.join()``
* pool checkouts — ``.acquire()`` on a buffer pool (backpressure blocks)
* bounded-queue puts — ``.put()`` — and ``time.sleep``
* calls to functions annotated ``# analyze: blocking``
* ``.wait()/.wait_for()`` on a *different* condition than the held one

This is exactly the bug class the paged KV cache fixed by parking pages
in ``_evicting`` and dropping the lock around the dirty store write; the
walk understands that pattern through explicit ``self._lock.release()`` /
``.acquire()`` toggles.

Companion rule: calling a method annotated ``# analyze: holds(_lock)``
without holding ``self._lock`` is flagged here too — the annotation is a
precondition, not a suggestion."""

from __future__ import annotations

import ast

from .core import Finding, LockWalk, Project, attr_chain

_WAIT_ATTRS = {"result", "join"}
_STORE_ATTRS = {"read", "write", "read_new"}
_OS_BLOCKING = {"pread", "pwrite", "fsync", "fdatasync", "sendfile"}
_STORE_BASES = {"TensorStore"}
_POOL_BASES = {"BufferPoolBase"}


def _is_subclass_of(project: Project, name: str | None,
                    bases: set[str]) -> bool:
    seen: set[str] = set()
    while name and name not in seen:
        if name in bases:
            return True
        seen.add(name)
        ci = project.resolve_class(name)
        name = ci.bases[0] if ci and ci.bases else None
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules:
        for ci in mod.classes.values():
            locks = project.class_locks(ci)
            if not locks:
                continue
            for fi in ci.methods.values():
                findings.extend(_check_fn(project, mod, ci, fi, locks))
    return findings


def _check_fn(project, mod, ci, fi, locks) -> list[Finding]:
    out: list[Finding] = []

    def attr_type(recv: str) -> str | None:
        # "self.store" -> class name of the attribute, when known
        if recv.startswith("self.") and recv.count(".") == 1:
            return ci.attr_types.get(recv.split(".", 1)[1])
        return None

    def blocking_reason(node: ast.Call, held: set[str]) -> str | None:
        chain = attr_chain(node.func)
        if chain is None:
            return None
        parts = chain.split(".")
        recv, attr = ".".join(parts[:-1]), parts[-1]
        if recv == "self" and attr in locks:
            return None                      # bare lock name, not a call
        if recv.startswith("self.") and recv.split(".", 1)[1] in locks:
            lock = recv.split(".", 1)[1]
            if attr in ("wait", "wait_for"):
                others = held - {lock}
                if lock in held and others:
                    return (f"condition wait on self.{lock} while also "
                            f"holding {sorted(others)}")
                return None                  # waiting its own condition
            return None                      # acquire/release/notify: toggles
        if chain == "time.sleep":
            return "time.sleep"
        if attr in _WAIT_ATTRS:
            return f"{chain}() waits on a future/thread"
        if recv == "os" and attr in _OS_BLOCKING:
            return f"{chain} is synchronous file I/O"
        recv_cls = attr_type(recv)
        last = parts[-2] if len(parts) >= 2 else ""
        if attr in _STORE_ATTRS and (
                _is_subclass_of(project, recv_cls, _STORE_BASES)
                or last in ("store", "_store")):
            return f"{chain}() is synchronous store I/O"
        if attr == "acquire" and (
                _is_subclass_of(project, recv_cls, _POOL_BASES)
                or last in ("pool", "_pool")):
            return f"{chain}() may block on pool backpressure"
        if attr == "put":
            return f"{chain}() may block on a bounded queue"
        callee = _resolve_self_call(project, ci, chain)
        if callee is not None and callee.blocking:
            return f"{callee.qualname} is annotated '# analyze: blocking'"
        return None

    def visit(node: ast.AST, held: set[str]) -> None:
        if not isinstance(node, ast.Call):
            return
        chain = attr_chain(node.func)
        callee = (_resolve_self_call(project, ci, chain)
                  if chain else None)
        if callee is not None and callee.holds:
            missing = callee.holds - held
            if missing and not mod.suppressed(node.lineno, "lock-blocking"):
                out.append(Finding(
                    mod.rel, node.lineno, "lock-blocking", fi.qualname,
                    f"call to {callee.qualname} requires holding "
                    f"{sorted('self.' + h for h in missing)} "
                    f"(annotated holds)"))
        if not held:
            return
        reason = blocking_reason(node, held)
        if reason and not mod.suppressed(node.lineno, "lock-blocking"):
            out.append(Finding(
                mod.rel, node.lineno, "lock-blocking", fi.qualname,
                f"blocking call while holding "
                f"{sorted('self.' + h for h in held)}: {reason}"))

    LockWalk(locks, visit).run(fi.node, initially=set(fi.holds))
    return out


def _resolve_self_call(project: Project, ci, chain: str | None):
    if chain and chain.startswith("self.") and chain.count(".") == 1:
        return project.lookup_method(ci, chain.split(".", 1)[1])
    return None
