"""Checker 1 — lock discipline: a field annotated ``# guarded-by: _lock``
(or listed in a module-level ``GUARDED_BY`` registry) may only be read or
written while ``self._lock`` is held.

Exemptions: ``__init__``/``__del__`` (the object is not shared yet /
no longer shared), methods annotated ``# analyze: pre-share``, and
methods annotated ``# analyze: holds(_lock)`` — those start the walk
with the lock already held (their call sites are checked by the
no-blocking checker's companion rule instead)."""

from __future__ import annotations

import ast

from .core import Finding, LockWalk, Project

_EXEMPT = {"__init__", "__del__"}


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules:
        for ci in mod.classes.values():
            guarded = project.class_guarded(ci)
            if not guarded:
                continue
            locks = project.class_locks(ci)
            for fi in ci.methods.values():
                if fi.name in _EXEMPT or fi.pre_share:
                    continue
                findings.extend(_check_fn(mod, ci, fi, guarded, locks))
    return findings


def _check_fn(mod, ci, fi, guarded, locks) -> list[Finding]:
    out: list[Finding] = []
    flagged: set[tuple[int, str]] = set()

    def visit(node: ast.AST, held: set[str]) -> None:
        if not (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded):
            return
        lock = guarded[node.attr]
        if lock in held:
            return
        if mod.suppressed(node.lineno, "lock-discipline"):
            return
        key = (node.lineno, node.attr)
        if key in flagged:
            return
        flagged.add(key)
        out.append(Finding(
            mod.rel, node.lineno, "lock-discipline", fi.qualname,
            f"access to self.{node.attr} (guarded-by {lock}) without "
            f"holding self.{lock}"))

    LockWalk(locks, visit).run(fi.node, initially=set(fi.holds))
    return out
