"""Docs drift gate: intra-repo markdown links must resolve, and every
example must import.

Two checks, both cheap enough for CI's ``docs`` job and for tier-1
(``tests/test_docs.py`` wraps the same functions):

* :func:`check_markdown_links` — every relative link target in the repo's
  markdown files exists on disk.  Catches renamed/moved docs, deleted
  baselines, and README references to files that never landed.
* :func:`check_example_imports` — every ``examples/*.py`` smoke-imports
  (module level only; the demos keep their work under ``main()``).
  Catches doc/code drift like renamed ``DecodeSpec`` fields or moved
  public API the examples still reference.

Usage::

    PYTHONPATH=src python tools/check_docs.py [repo_root]
"""

from __future__ import annotations

import importlib.util
import os
import re
import sys

# [text](target) — target captured up to the first unescaped ')'
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
_SKIP_DIRS = {".git", "__pycache__", ".hypothesis", "node_modules",
              ".pytest_cache", ".ruff_cache"}


def _markdown_files(root: str) -> list[str]:
    found = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                found.append(os.path.join(dirpath, name))
    return sorted(found)


def check_markdown_links(root: str) -> list[str]:
    """Failure messages for relative markdown links that do not resolve."""
    failures = []
    for path in _markdown_files(root):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(_SKIP_PREFIXES):
                continue
            target = target.split("#", 1)[0]   # strip anchors
            if not target:
                continue
            base = root if target.startswith("/") else os.path.dirname(path)
            resolved = os.path.normpath(
                os.path.join(base, target.lstrip("/")))
            if not os.path.exists(resolved):
                rel = os.path.relpath(path, root)
                failures.append(f"{rel}: broken link -> {target}")
    return failures


def check_example_imports(root: str) -> list[str]:
    """Failure messages for examples/*.py files that fail to import."""
    failures = []
    examples = os.path.join(root, "examples")
    if not os.path.isdir(examples):
        return [f"missing examples directory at {examples}"]
    # examples import `benchmarks.*` helpers; make the repo root importable
    # the way running from a checkout does
    if root not in sys.path:
        sys.path.insert(0, root)
    for name in sorted(os.listdir(examples)):
        if not name.endswith(".py"):
            continue
        mod_name = f"_docs_check_example_{name[:-3]}"
        spec = importlib.util.spec_from_file_location(
            mod_name, os.path.join(examples, name))
        module = importlib.util.module_from_spec(spec)
        try:
            spec.loader.exec_module(module)
        except BaseException as e:   # noqa: BLE001 — report, don't crash
            failures.append(f"examples/{name}: import failed: {e!r}")
        finally:
            sys.modules.pop(mod_name, None)
    return failures


def main(argv: list[str] | None = None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    root = os.path.abspath(
        args[0] if args else os.path.join(os.path.dirname(__file__), ".."))
    failures = check_markdown_links(root) + check_example_imports(root)
    for msg in failures:
        print(f"check_docs: FAIL {msg}")
    if not failures:
        n_md = len(_markdown_files(root))
        print(f"check_docs: OK ({n_md} markdown files, examples import)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
