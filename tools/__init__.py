"""Repo tooling: doc checks (`check_docs.py`) and the concurrency-contract
static analyzer (`python -m tools.analyze`)."""
